"""Pipeline parallelism: pipelined == sequential, on a real multi-device
host mesh (subprocess with XLA_FLAGS so the main test process keeps 1
device)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.pipeline import pipelined_apply

mesh = jax.make_mesh((4,), ("pod",))
n_stages, d, batch = 4, 16, 8
key = jax.random.PRNGKey(0)
# 4 stages, each one tanh-linear layer
w = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)
params = {"w": w}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

x = jax.random.normal(jax.random.PRNGKey(1), (batch, d))

# sequential reference
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s])

out = pipelined_apply(mesh, stage_fn, params, x, pipe_axis="pod", n_micro=4)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
print("PIPELINE_OK", err)
"""


def test_pipeline_matches_sequential():
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    # keep the platform pin: without it a TPU-plugin host spins on GCP
    # metadata queries inside the hermetic subprocess
    for var in ("JAX_PLATFORMS", "TPU_SKIP_MDS_QUERY", "HOME"):
        if var in os.environ:
            env[var] = os.environ[var]
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "PIPELINE_OK" in r.stdout, (r.stdout, r.stderr[-2000:])

"""End-to-end tests of the executable deployment flow.

compile -> plan -> execute: the plan executor must be *bit-exact* against
the model-level ``forward_w8a8`` path (the integer arithmetic is fully
deterministic, so any mismatch is a lowering/dispatch bug, not numerics);
the plan must round-trip through its serialized form; and every scheduled
node's engine assignment must agree with ``ita_supports``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import heterogeneous as het
from repro.deploy import api
from repro.deploy.executor import _run_node, bind_encoder_weights, execute
from repro.deploy.lowering import build_runtime_encoder_graph, lower, schedule
from repro.deploy.patterns import deploy_pipeline, node_opdesc
from repro.deploy.plan import DeploymentPlan, PlanNode
from repro.models import encoder as EN


def plan_and_bind(cfg, seq_len=None, *, params=None, head_by_head=False,
                  backend=het.Backend.W8A8):
    """compile() + bind, unpacked to (plan, weights, qp) for these tests."""
    m = api.compile(cfg, backend=backend, seq_len=seq_len,
                    head_by_head=head_by_head, use_cache=False)
    weights, qp = m.bind(params=params)
    return m.artifact, weights, qp


@pytest.fixture(scope="module")
def mobilebert_setup():
    cfg = reduced(get_config("mobilebert"))
    key = jax.random.PRNGKey(2)
    params = EN.init_params(cfg, key)
    qp = EN.quantize_params(cfg, params)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab, jnp.int32)}
    return cfg, params, qp, batch


class TestBitExactness:
    def test_w8a8_backend_matches_model(self, mobilebert_setup):
        cfg, params, qp, batch = mobilebert_setup
        plan, weights, _ = plan_and_bind(cfg, seq_len=64, params=params)
        ref = EN.forward_w8a8(cfg, qp, batch)
        got = execute(plan, weights, batch, backend=het.Backend.W8A8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_ita_backend_matches_model(self, mobilebert_setup):
        """Pallas kernels (interpret on CPU) produce the identical ints."""
        cfg, params, qp, batch = mobilebert_setup
        plan, weights, _ = plan_and_bind(cfg, seq_len=64, params=params)
        ref = EN.forward_w8a8(cfg, qp, batch)
        got = execute(plan, weights, batch, backend=het.Backend.ITA)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_head_by_head_matches_model_schedule(self, mobilebert_setup):
        """The per-head split plan reproduces the model's ita_head_by_head
        branch exactly (int32 partial accumulation is associative)."""
        cfg, params, qp, batch = mobilebert_setup
        plan, weights, _ = plan_and_bind(cfg, seq_len=64, params=params,
                                         head_by_head=True)
        ref = EN.forward_w8a8(cfg.replace(ita_head_by_head=True), qp, batch)
        got = execute(plan, weights, batch, backend=het.Backend.W8A8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_jitted_executor_and_features_output(self):
        """Patch-input encoder (no vocab): jitted plan == model features."""
        cfg = get_config("dinov2-small").replace(n_layers=1, n_patches=64, max_seq=64)
        key = jax.random.PRNGKey(3)
        params = EN.init_params(cfg, key)
        qp = EN.quantize_params(cfg, params)
        model = api.compile(cfg, seq_len=64, use_cache=False)
        session = model.session(1, params=params)  # jitted forward
        batch = {"patches": jax.random.randint(key, (1, 64, cfg.d_model), -64, 64, jnp.int8)}
        ref = EN.forward_w8a8(cfg, qp, batch)
        got = session.forward(batch)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


class TestPlanArtifact:
    def test_json_round_trip(self, mobilebert_setup):
        cfg, params, qp, batch = mobilebert_setup
        plan, weights, _ = plan_and_bind(cfg, seq_len=64, params=params)
        restored = DeploymentPlan.from_json(plan.to_json())
        assert restored == plan
        ref = execute(plan, weights, batch)
        got = execute(restored, weights, batch)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_plan_is_static_and_complete(self, mobilebert_setup):
        cfg, params, _, _ = mobilebert_setup
        plan, weights, _ = plan_and_bind(cfg, seq_len=64, params=params)
        plan.validate()
        # every accelerated geometry has a tiling solution
        for n in plan.nodes:
            if n.engine == "ita" and n.op in ("MatMul", "MHA", "MHAHead"):
                assert n.name in plan.tilings, n.name
        # every activation has a static offset; weights have none
        for t in plan.tensors.values():
            if t.weight:
                assert t.offset is None
                assert t.name in weights
        assert plan.memory_peak > 0

    def test_schedule_is_topological(self):
        cfg = reduced(get_config("whisper-tiny-encoder"))
        g = deploy_pipeline(build_runtime_encoder_graph(cfg, 64))
        order = schedule(g)
        assert len(order) == len(g.nodes)
        seen = set(g.inputs) | set(g.weights)
        for n in order:
            for t in n.inputs:
                assert t in seen, (n.name, t)
            seen.update(n.outputs)

    def test_schedule_duplicate_inputs_from_one_producer(self):
        """A node consuming the same tensor twice must still wait for ALL
        its producers (edge dedup regression)."""
        from repro.deploy.graph import Graph

        g = Graph()
        for t in ("in", "a", "b", "c"):
            g.add_tensor(t, (4,))
        g.inputs.append("in")
        g.add_node("LayerNorm", ["in"], ["b"], name="B", dims=(4,))
        g.add_node("LayerNorm", ["in"], ["a"], name="A", dims=(4,))
        g.add_node("Add", ["a", "a", "b"], ["c"], name="C", dims=(4,))
        order = [n.name for n in schedule(g)]
        assert order.index("C") > order.index("A")
        assert order.index("C") > order.index("B")


class TestEngineAssignment:
    @pytest.mark.parametrize("arch", ["mobilebert", "dinov2-small", "whisper-tiny-encoder"])
    def test_engines_agree_with_ita_supports(self, arch):
        """The plan's static engine column is exactly ita_supports."""
        cfg = get_config(arch)
        plan = lower(cfg, seq_len=min(cfg.max_seq, 128))
        for n in plan.nodes:
            want = "ita" if het.ita_supports(node_opdesc(n, plan.granule), plan.granule) \
                else "cluster"
            assert n.engine == want, (n.name, n.op, n.engine, want)

    def test_misaligned_head_dim_falls_back(self):
        """reduced() uses head_dim=32: MHA must land on the cluster."""
        cfg = reduced(get_config("mobilebert"))
        plan = lower(cfg, seq_len=64)
        mha = [n for n in plan.nodes if n.op == "MHA"]
        assert mha and all(n.engine == "cluster" for n in mha)
        # aligned GEMMs still accelerate
        assert any(n.engine == "ita" for n in plan.nodes if n.op == "MatMul")

    def test_full_head_dim_accelerates(self):
        cfg = get_config("mobilebert").replace(n_layers=1)
        plan = lower(cfg)
        mha = [n for n in plan.nodes if n.op == "MHA"]
        assert mha and all(n.engine == "ita" for n in mha)


class TestGemmActivations:
    """Satellite regression: the GEMM runner must execute every activation
    the plan vocabulary admits, and fail loudly on anything else — the old
    code silently mapped unknown activations to identity."""

    def _node_and_env(self, act):
        key = jax.random.PRNGKey(0)
        x = jax.random.randint(key, (1, 8, 64), -127, 128, jnp.int8)
        w = jax.random.randint(key, (64, 64), -127, 128, jnp.int8)
        node = PlanNode(
            name="g0", op="MatMul", kind="gemm", engine="cluster",
            inputs=("x", "w"), outputs=("y",),
            attrs={"dims": (8, 64, 64), "scales": (0.05, 0.01, 0.05),
                   "activation": act},
        )
        return node, {"x": x, "w": w}

    def test_relu_executes_relu(self):
        from repro.core.quant_linear import ACT_RELU, make_qlinear_params, qlinear_i8

        node, env = self._node_and_env("relu")
        got = _run_node(node, env, het.DEFAULT_TABLE, het.Backend.W8A8)
        want = qlinear_i8(env["x"], env["w"], None,
                          make_qlinear_params(0.05, 0.01, 0.05, ACT_RELU))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # and relu is genuinely not identity on this data
        iden, _ = self._node_and_env("identity")
        got_id = _run_node(iden, env, het.DEFAULT_TABLE, het.Backend.W8A8)
        assert not np.array_equal(np.asarray(got), np.asarray(got_id))

    def test_unknown_activation_raises(self):
        node, env = self._node_and_env("swish")
        with pytest.raises(NotImplementedError, match="swish"):
            _run_node(node, env, het.DEFAULT_TABLE, het.Backend.W8A8)


class TestDefaultTable:
    def test_populated_at_import(self):
        kinds = het.DEFAULT_TABLE.kinds()
        for kind in ("gemm", "mha", "softmax", "gelu", "layernorm", "add",
                     "headaccum", "embed", "classifier", "dequant"):
            assert kind in kinds, kind

    def test_ita_overrides_are_pallas(self):
        """ITA backend resolves to different callables than W8A8 for the
        accelerated kinds (Pallas vs XLA arithmetic)."""
        op = het.OpDesc("gemm", shapes=((128, 128), (128, 128)))
        _, fn_w8a8 = het.DEFAULT_TABLE.resolve(op, het.Backend.W8A8)
        _, fn_ita = het.DEFAULT_TABLE.resolve(op, het.Backend.ITA)
        assert fn_w8a8 is not fn_ita

    def test_float_backend_stays_on_cluster(self):
        op = het.OpDesc("gemm", shapes=((128, 128), (128, 128)))
        engine, _ = het.DEFAULT_TABLE.resolve(op, het.Backend.FLOAT)
        assert engine is het.Engine.CLUSTER


class TestWeightBinding:
    def test_all_plan_weights_bound(self, mobilebert_setup):
        cfg, params, qp, _ = mobilebert_setup
        plan = lower(cfg, seq_len=64)
        weights = bind_encoder_weights(plan, cfg, qp)
        assert set(weights) == set(plan.weight_names)
        # wq/wk/wv slices recompose the fused wqkv exactly
        h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        lp0 = jax.tree.map(lambda a: a[0], qp["layers"])
        fused = np.asarray(lp0["attn"]["wqkv"]["w_q"])
        cat = np.concatenate(
            [np.asarray(weights["l0_wq"]), np.asarray(weights["l0_wk"]),
             np.asarray(weights["l0_wv"])], axis=1)
        np.testing.assert_array_equal(cat, fused[:, : (h + 2 * hkv) * hd])

"""Radix prefix cache + refcounted copy-on-write paged KV (ISSUE 9).

Acceptance contract: requests sharing a prompt prefix attach resident
pool blocks (``PrefixIndex`` match -> ``attach_prefix``) and prefill only
the novel suffix — an exact-prompt repeat admits with *zero* prefill
dispatches — while every trajectory stays bit-exact vs its independent
unshared reference on both ``w8a8`` and ``ita``; the first write into a
shared block copy-on-writes it, so siblings and the index never observe
a neighbour's decode; eviction respects refcounts
(``KVCapacityError.evictable`` never names a slot whose blocks are all
shared; reclaim never frees a block a live request holds); and the
KV-sharing audit (rules KV006/KV007) is clean after any schedule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.deploy import api
from repro.deploy.engine import Engine, RequestStatus
from repro.deploy.paging import BlockAllocator, PoolExhausted, blocks_for_rows
from repro.deploy.prefix import PrefixIndex, PrefixMatch
from repro.deploy.verify import (
    KVSharingState,
    KVWrite,
    PlanVerificationError,
    check_sharing,
    verify_sharing,
)
from repro.models import transformer as T

SEQ = 8
MAX_LEN = 40
BLOCK = 4


@pytest.fixture(scope="module")
def olmo():
    # Running late in the full suite, the process carries hundreds of live
    # jitted executables; on a single-core box the XLA backend has been
    # observed to segfault compiling this module's scan-based reference
    # oracle under that load.  Dropping the accumulated caches first keeps
    # the heavy compiles in this module starting from a clean JIT arena.
    jax.clear_caches()
    cfg = reduced(get_config("olmo-1b"))
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    return cfg, params


def _compile(cfg, backend="w8a8", *, max_len=MAX_LEN, kv_blocks=30,
             kv_block_size=BLOCK, prefix_cache=True):
    return api.compile(cfg, backend=backend, seq_len=SEQ, max_len=max_len,
                       kv_block_size=kv_block_size, kv_blocks=kv_blocks,
                       prefix_cache=prefix_cache, use_cache=False)


def _tokens(cfg, n, seed=0):
    return [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, cfg.vocab, jnp.int32)]


def reference_trajectory(cfg, qp, prompt, max_new, max_len, eos_id=None):
    """Independent single-request greedy oracle (same as test_engine)."""
    lg, cache = T.prefill_w8a8(
        cfg, qp, {"tokens": jnp.asarray(prompt[:SEQ], jnp.int32)[None]},
        max_len)
    out, depth = [], SEQ
    while True:
        if depth < len(prompt):
            nxt = prompt[depth]
        else:
            nxt = int(jnp.argmax(lg[0, -1, : cfg.vocab]))
            out.append(nxt)
            if eos_id is not None and nxt == eos_id:
                return out, "eos"
            if len(out) >= max_new:
                return out, "length"
        if depth >= max_len:
            return out, "kv_capacity"
        lg, cache = T.decode_step_w8a8(cfg, qp, cache,
                                       jnp.asarray([[nxt]], jnp.int32))
        depth += 1


# ---------------------------------------------------------------------------
# Allocator: refcounts, fork, copy-on-write
# ---------------------------------------------------------------------------

class TestAllocatorSharing:
    def test_fork_shares_and_free_decrements(self):
        a = BlockAllocator(6)
        blocks = a.allocate(3)
        assert [a.refcount(b) for b in blocks] == [1, 1, 1]
        assert a.fork(blocks[:2]) == blocks[:2]
        assert a.n_shared == 2 and a.n_free == 3
        # first free: refcounts drop, nothing returns to the pool
        a.free(blocks[:2])
        assert a.n_free == 3
        assert [a.refcount(b) for b in blocks] == [1, 1, 1]
        # last reference out: blocks rejoin the free list, lowest-id first
        a.free(blocks)
        assert a.n_free == 6 and a.n_shared == 0
        assert a.allocate(3) == blocks  # deterministic reissue

    def test_fork_dead_block_is_loud_and_atomic(self):
        a = BlockAllocator(4)
        (b,) = a.allocate(1)
        with pytest.raises(ValueError, match="not allocated"):
            a.fork([b, 99])
        assert a.refcount(b) == 1  # all-or-nothing: b was not bumped

    def test_cow_exclusive_is_in_place(self):
        a = BlockAllocator(4)
        (b,) = a.allocate(1)
        assert a.cow(b) == (b, False)
        assert a.n_free == 3 and a.refcount(b) == 1

    def test_cow_shared_materializes_private_copy(self):
        a = BlockAllocator(4)
        (b,) = a.allocate(1)
        a.fork([b])
        fresh, copied = a.cow(b)
        assert copied and fresh != b
        assert a.refcount(b) == 1 and a.refcount(fresh) == 1
        assert a.n_shared == 0
        # conservation: 2 live + 2 free
        assert a.n_free == 2

    def test_cow_exhausted_pool_is_loud_without_mutation(self):
        a = BlockAllocator(1)
        (b,) = a.allocate(1)
        a.fork([b])
        with pytest.raises(PoolExhausted):
            a.cow(b)
        assert a.refcount(b) == 2  # untouched

    def test_double_free_still_loud(self):
        a = BlockAllocator(2)
        (b,) = a.allocate(1)
        a.free([b])
        with pytest.raises(ValueError, match="double free"):
            a.free([b])


# ---------------------------------------------------------------------------
# PrefixIndex: match / insert / LRU reclaim
# ---------------------------------------------------------------------------

class TestPrefixIndex:
    def _index(self, n_blocks=12):
        return PrefixIndex(BlockAllocator(n_blocks), BLOCK)

    def test_empty_index_misses(self):
        idx = self._index()
        m = idx.match(list(range(10)))
        assert m == PrefixMatch((), 0) and not m.hit

    def test_insert_then_full_and_partial_match(self):
        idx = self._index()
        alloc = idx._alloc
        toks = list(range(10))  # 2 full blocks + 2-row tail
        chain = alloc.allocate(blocks_for_rows(10, BLOCK))
        logits = np.arange(8, dtype=np.float32)
        assert idx.insert(toks, chain, logits) == 3
        assert idx.n_blocks == 3
        assert [alloc.refcount(b) for b in chain] == [2, 2, 2]

        full = idx.match(toks)
        assert full.full and full.rows == 10 and full.blocks == tuple(chain)
        np.testing.assert_array_equal(full.logits, logits)
        # longer prompt with the same leading blocks: partial hit on the
        # full-block prefix only (the tail rows are not row-addressable)
        part = idx.match(toks + [77, 78])
        assert not part.full and part.rows == 8
        assert part.blocks == tuple(chain[:2])
        # diverging first block: miss
        assert not idx.match([99] * 10).hit

    def test_insert_validates_chain_and_logits(self):
        idx = self._index()
        chain = idx._alloc.allocate(2)
        with pytest.raises(ValueError, match="chain"):
            idx.insert(list(range(10)), chain, np.zeros(4))
        with pytest.raises(ValueError, match="logits"):
            idx.insert(list(range(8)), chain, None)

    def test_duplicate_insert_keeps_incumbents(self):
        idx = self._index()
        alloc = idx._alloc
        toks = list(range(8))
        first = alloc.allocate(2)
        idx.insert(toks, first, np.zeros(4))
        second = alloc.allocate(2)
        assert idx.insert(toks, second, np.ones(4)) == 0
        assert idx.match(toks).blocks == tuple(first)
        assert [alloc.refcount(b) for b in second] == [1, 1]

    def test_reclaim_is_lru_and_respects_refcounts(self):
        idx = self._index()
        alloc = idx._alloc
        cold, hot = list(range(8)), list(range(100, 108))
        cold_chain = alloc.allocate(2)
        idx.insert(cold, cold_chain, np.zeros(4))
        hot_chain = alloc.allocate(2)
        idx.insert(hot, hot_chain, np.zeros(4))
        alloc.free(cold_chain)
        alloc.free(hot_chain)  # index is now the only holder of all 4
        idx.match(hot)  # refresh hot's ticks
        assert idx.reclaimable() == 4
        assert idx.reclaim(1) >= 1
        # the cold prompt lost (part of) its chain first; hot is intact
        assert not idx.match(cold).full
        assert idx.match(hot).full

        # a block a live request still shares is never reclaimed
        alloc.fork([idx.match(hot).blocks[0]])
        freed = idx.reclaim()
        assert all(alloc.refcount(b) != 1 or b not in idx.pinned_blocks()
                   for b in range(1, alloc.n_blocks + 1))
        m = idx.match(hot)
        assert not m.full  # terminal + leaf went; shared node block stayed
        assert m.rows == 4 and freed >= 1

    def test_reclaim_protect_guard(self):
        idx = self._index()
        alloc = idx._alloc
        chain = alloc.allocate(2)
        idx.insert(list(range(8)), chain, np.zeros(4))
        alloc.free(chain)
        assert idx.reclaim(protect=chain) == 0
        assert idx.reclaim() == 2
        assert alloc.n_free == alloc.n_blocks

    def test_drop_all_releases_everything(self):
        idx = self._index()
        alloc = idx._alloc
        chain = alloc.allocate(3)
        idx.insert(list(range(10)), chain, np.zeros(4))
        alloc.free(chain)
        assert idx.drop_all() == 3
        assert alloc.n_free == alloc.n_blocks and idx.n_blocks == 0


# ---------------------------------------------------------------------------
# KV-sharing audit: KV006 / KV007 mutation tests
# ---------------------------------------------------------------------------

class TestSharingAudit:
    def _clean(self):
        # slot0 shares blocks 1,2 with the index; block 3 is private
        return KVSharingState(
            n_blocks=8,
            refcounts={1: 2, 2: 2, 3: 1},
            tables={0: (1, 2, 3)},
            index_blocks=(1, 2),
        )

    def test_clean_state_passes(self):
        assert verify_sharing(self._clean()) == []
        assert check_sharing(self._clean(), strict=True) == []

    @pytest.mark.parametrize("state,rule", [
        # dead block referenced by a table
        (KVSharingState(n_blocks=8, refcounts={}, tables={0: (3,)}), "KV006"),
        # out-of-pool (and scratch) ids referenced
        (KVSharingState(n_blocks=8, refcounts={1: 1}, tables={0: (1,)},
                        index_blocks=(0,)), "KV006"),
        (KVSharingState(n_blocks=8, refcounts={1: 1}, tables={0: (1, 9)}),
         "KV006"),
        # refcount leak (2 recorded, 1 held) and underflow (1 recorded,
        # 2 held)
        (KVSharingState(n_blocks=8, refcounts={1: 2}, tables={0: (1,)}),
         "KV006"),
        (KVSharingState(n_blocks=8, refcounts={1: 1},
                        tables={0: (1,), 1: (1,)}), "KV006"),
        # write outside the writer's own table
        (KVSharingState(n_blocks=8, refcounts={1: 1, 2: 1},
                        tables={0: (1,), 1: (2,)},
                        writes=(KVWrite(0, 2),)), "KV007"),
        # in-place write into a shared block (no COW)
        (KVSharingState(n_blocks=8, refcounts={1: 2},
                        tables={0: (1,), 1: (1,)},
                        writes=(KVWrite(0, 1, cow=False),)), "KV007"),
        # COW write whose target is still shared
        (KVSharingState(n_blocks=8, refcounts={1: 2},
                        tables={0: (1,), 1: (1,)},
                        writes=(KVWrite(0, 1, cow=True),)), "KV007"),
    ], ids=["dead-block", "scratch-ref", "out-of-range", "refcount-leak",
            "refcount-underflow", "foreign-write", "shared-write-no-cow",
            "cow-still-shared"])
    def test_each_mutation_caught_by_exact_rule(self, state, rule):
        diags = verify_sharing(state)
        assert diags and all(d.rule == rule for d in diags), \
            [str(d) for d in diags]
        assert all(d.severity == "error" for d in diags)
        with pytest.raises(PlanVerificationError) as ei:
            check_sharing(state, context="mutation")
        assert rule in str(ei.value)

    def test_cowed_exclusive_write_is_legal(self):
        state = KVSharingState(
            n_blocks=8, refcounts={1: 2, 4: 1},
            tables={0: (4,), 1: (1,)}, index_blocks=(1,),
            writes=(KVWrite(0, 4, cow=True),),
        )
        assert verify_sharing(state) == []


# ---------------------------------------------------------------------------
# Session: attach_prefix + copy-on-write before any shared write
# ---------------------------------------------------------------------------

class TestSessionSharing:
    def test_attach_cow_isolates_siblings_bit_exactly(self, olmo):
        """Slot 1 attaches slot 0's whole chain; both then decode their
        own continuations.  The divergent writes must COW — afterwards
        the two trajectories differ while slot 0's original rows are
        untouched, and the sharing audit stays clean throughout."""
        cfg, params = olmo
        sess = _compile(cfg, kv_blocks=12).session(2, params=params)
        alloc = sess.allocator
        # 10 rows: 2 full blocks + a half-filled tail block — the tail is
        # where attach-then-write MUST copy-on-write
        prompt = _tokens(cfg, SEQ + 2, seed=11)
        sess.prefill_chunk(0, jnp.asarray([prompt[:SEQ]], jnp.int32), 0)
        lg = sess.prefill_chunk(0, jnp.asarray([prompt[2:]], jnp.int32), 2)
        chain = sess.block_chain(0)
        assert len(chain) == blocks_for_rows(SEQ + 2, BLOCK)

        sess.attach_prefix(1, chain, SEQ + 2)
        assert sess.block_chain(1) == chain
        assert int(sess.pos[1]) == SEQ + 2
        assert alloc.n_shared == len(chain)
        assert verify_sharing(sess.sharing_state()) == []

        # identical next token on both slots: the decode writes land in
        # the shared tail block -> each writer COWs before writing
        nxt = int(jnp.argmax(lg[0, -1, : cfg.vocab]))
        before = sess.cow_copies
        lg2 = sess.decode(jnp.asarray([[nxt], [nxt]], jnp.int32))
        assert sess.cow_copies > before
        assert alloc.n_shared < len(chain)  # tail block(s) privatized
        # both lanes read identical context -> identical logits rows
        np.testing.assert_array_equal(np.asarray(lg2[0, -1]),
                                      np.asarray(lg2[1, -1]))
        assert verify_sharing(sess.sharing_state()) == []
        # freeing the sharer returns only its private copies
        held = sess.blocks_held(1)
        free_before = sess.blocks_free
        sess.free_slot(1)
        assert sess.blocks_free < free_before + held  # shared stayed live
        assert verify_sharing(sess.sharing_state()) == []

    def test_attach_validations(self, olmo):
        cfg, params = olmo
        sess = _compile(cfg, kv_blocks=8).session(2, params=params)
        prompt = _tokens(cfg, SEQ, seed=3)
        sess.prefill_chunk(0, jnp.asarray([prompt], jnp.int32), 0)
        chain = sess.block_chain(0)
        with pytest.raises(RuntimeError, match="live slot"):
            sess.attach_prefix(0, chain, SEQ)
        with pytest.raises(ValueError):
            sess.attach_prefix(1, chain, SEQ + 1)  # chain/rows mismatch

    def test_evictable_excludes_all_shared_slots(self, olmo):
        """The regression the tentpole guards: a slot whose blocks are
        ALL shared frees nothing when evicted, so the structured
        capacity error must not name it."""
        cfg, params = olmo
        sess = _compile(cfg, kv_blocks=4).session(3, params=params)
        prompt = _tokens(cfg, SEQ, seed=5)
        sess.prefill_chunk(0, jnp.asarray([prompt], jnp.int32), 0)  # 2 blocks
        sess.attach_prefix(1, sess.block_chain(0), SEQ)  # all-shared slot
        # slot 2 wants 2 blocks; 2 free -> fits.  Then growing past the
        # pool must name ONLY slot 0 (exclusive owner is... both 0 and 1
        # share everything; neither holds an exclusive block!).  Fill the
        # pool with slot 2 instead and let 0 hold the only private block.
        sess.prefill_chunk(2, jnp.asarray([prompt], jnp.int32), 0)
        assert sess.blocks_free == 0
        with pytest.raises(api.KVCapacityError) as ei:
            sess.decode(jnp.asarray([[1], [1], [1]], jnp.int32),
                        active=jnp.asarray([True, False, False]))
        e = ei.value
        assert e.reason == "pool" and e.slots == (0,)
        # slot 1 shares everything it holds -> not evictable; slot 2's
        # blocks are exclusively its own -> evictable
        assert e.evictable == (2,)


# ---------------------------------------------------------------------------
# Engine: shared-prefix serving, bit-exact on both backends
# ---------------------------------------------------------------------------

class TestEnginePrefixBitExact:
    @pytest.mark.parametrize("backend", ["w8a8", "ita"])
    def test_shared_prompt_trajectories_bit_exact(self, olmo, backend):
        """Sequential re-submissions of a shared prompt: the repeat is a
        zero-prefill full hit, the extended prompt a partial hit that
        prefills only its suffix — all three token streams equal their
        independent unshared references."""
        cfg, params = olmo
        engine = Engine(_compile(cfg, backend), 2, params=params)
        qp = engine.session.qp
        base = _tokens(cfg, 2 * SEQ + 2, seed=21)  # 18 rows: 4 blocks + tail
        longer = base + _tokens(cfg, 6, seed=22)  # shares base verbatim
        plans = [(base, 3), (base, 3), (longer, 2)]
        refs = [reference_trajectory(cfg, qp, p, n, MAX_LEN)
                for p, n in plans]

        h0 = engine.submit(*plans[0])
        engine.run_until_idle(max_steps=200)
        h1 = engine.submit(*plans[1])
        h2 = engine.submit(*plans[2])
        engine.run_until_idle(max_steps=200)

        for h, (toks, reason) in zip([h0, h1, h2], refs):
            assert h.status is RequestStatus.DONE
            assert h.tokens == toks, (h.rid, h.tokens, toks)
            assert h.finish_reason == reason
        s = engine.stats
        assert s.prefix_lookups == 3 and s.prefix_hits == 2
        assert s.full_prefix_hits == 1  # the exact repeat skipped prefill
        assert s.prefix_hit_blocks >= 5 + 4  # full chain + base's 4 nodes
        assert s.prefix_hit_rate() == pytest.approx(2 / 3)
        assert s.cow_copies >= 1  # decode into the shared tail block
        assert engine.audit_sharing() == []

    def test_concurrent_identical_prompts_defer_then_hit(self, olmo):
        """All-at-once identical submissions: the head prefills once,
        admission defers the rest until the prefix lands, and they admit
        as zero-prefill full hits — 1x prefill cost for N requests."""
        cfg, params = olmo
        engine = Engine(_compile(cfg), 2, params=params)
        qp = engine.session.qp
        prompt = _tokens(cfg, 4 * SEQ, seed=31)
        ref, _ = reference_trajectory(cfg, qp, prompt, 2, MAX_LEN)

        handles = [engine.submit(prompt, 2) for _ in range(3)]
        engine.run_until_idle(max_steps=300)
        for h in handles:
            assert h.status is RequestStatus.DONE and h.tokens == ref
        s = engine.stats
        assert s.full_prefix_hits == 2
        # exactly ONE request's worth of prompt tokens hit the prefill path
        assert s.prompt_tokens_prefilled == 4 * SEQ
        assert engine.audit_sharing() == []

    def test_eviction_under_pressure_never_corrupts_siblings(self, olmo):
        """Undersized pool + shared prefixes: some requests finish with
        kv_capacity, but every token any request DID emit must match its
        independent reference — eviction decrements refcounts, it never
        reclaims a sibling's shared rows."""
        cfg, params = olmo
        engine = Engine(_compile(cfg, kv_blocks=9), 2, params=params)
        qp = engine.session.qp
        base = _tokens(cfg, 2 * SEQ, seed=41)
        prompts = [base + _tokens(cfg, 4, seed=s) for s in (42, 43, 44)]
        budgets = [2, 6, 6]  # the head fits outright; the rest squeeze
        refs = [reference_trajectory(cfg, qp, p, n, MAX_LEN)[0]
                for p, n in zip(prompts, budgets)]

        handles = [engine.submit(p, n) for p, n in zip(prompts, budgets)]
        engine.run_until_idle(max_steps=400)
        for h, ref in zip(handles, refs):
            assert h.status is RequestStatus.DONE
            assert h.finish_reason in ("length", "kv_capacity")
            # every token any request DID emit is its reference's — a
            # sibling's eviction never rewrote shared rows underneath it
            assert h.tokens == ref[: len(h.tokens)], (h.rid, h.tokens, ref)
        assert handles[0].finish_reason == "length"
        assert engine.audit_sharing() == []

    def test_prefix_cache_off_by_default_and_fingerprinted(self, olmo):
        cfg, _ = olmo
        on = _compile(cfg)
        off = _compile(cfg, prefix_cache=False)
        assert on.fingerprint != off.fingerprint
        with pytest.raises(ValueError, match="prefix_cache"):
            api.compile(cfg, seq_len=SEQ, max_len=MAX_LEN,
                        prefix_cache=True, use_cache=False)  # dense decoder

    def test_engine_without_prefix_cache_has_no_index(self, olmo):
        cfg, params = olmo
        engine = Engine(_compile(cfg, prefix_cache=False), 1, params=params)
        assert engine.prefix_index is None
        prompt = _tokens(cfg, SEQ, seed=51)
        h = engine.submit(prompt, 2)
        engine.run_until_idle(max_steps=100)
        assert h.status is RequestStatus.DONE
        assert engine.stats.prefix_lookups == 0
        assert engine.audit_sharing() == []

"""Hypothesis property tests (all modules), gathered behind one guard.

The ``[test]`` extra installs hypothesis; where it is missing this module
skips at collection (``pytest.importorskip``) and the deterministic suites
keep running — the suite degrades gracefully instead of breaking
collection.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")

import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ilayernorm as iln
from repro.core import itamax as im
from repro.deploy import memory, tiler
from repro.deploy.graph import Graph
from repro.quant.qparams import (
    MULT_MAX,
    SHIFT_MAX,
    SHIFT_MIN,
    requantize,
    requantize_wide,
    rounding_rshift,
)


def _requant_gold(acc, mult, shift, zp=0):
    """Arbitrary-precision (python int) reference of requantize."""
    out = (int(acc) * int(mult) + (1 << (shift - 1))) >> shift
    return int(np.clip(out + zp, -128, 127))


class TestTilerProperties:
    @given(
        m=st.integers(1, 2048), n=st.integers(1, 2048), k=st.integers(1, 2048)
    )
    @settings(max_examples=60, deadline=None)
    def test_property_always_feasible(self, m, n, k):
        t = tiler.solve_gemm_tiling(m, n, k)
        assert t.l1_bytes <= tiler.ITA_L1_BYTES
        assert t.useful_ops == 2 * m * n * k


def _random_graph(rng) -> Graph:
    """Random branching DAG over 2-D int8 tensors (shared helper)."""
    g = Graph()
    live = [g.add_tensor("in", (int(rng.integers(1, 64)), 32))]
    g.inputs.append("in")
    for i in range(int(rng.integers(2, 25))):
        src = [live[int(rng.integers(0, len(live)))]]
        if rng.random() < 0.4 and len(live) > 1:
            src.append(live[int(rng.integers(0, len(live)))])
        out = g.add_tensor(f"t{i}", (int(rng.integers(1, 64)), 32))
        g.add_node("Add" if len(src) > 1 else "LayerNorm", src, [out],
                   dims=g.tensors[out].shape)
        live.append(out)
    g.outputs.append(live[-1])
    return g


class TestMemoryPlannerProperties:
    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_random_graphs_no_overlap(self, seed):
        """Random branching DAGs: planner must never alias live tensors."""
        g = _random_graph(np.random.default_rng(seed))
        plan = memory.plan_memory(g)
        assert plan.check_no_overlap()
        assert plan.peak >= memory.peak_lower_bound(g)

    @given(seed=st.integers(0, 10_000), n_pers=st.integers(1, 6))
    @settings(max_examples=25, deadline=None)
    def test_property_persistent_no_overlap_and_bounded(self, seed, n_pers):
        """KV-cache-style persistent tensors (whole-schedule lifetimes):
        no aliasing with any transient, peak bracketed by the lower bound
        and the everything-is-live upper bound."""
        rng = np.random.default_rng(seed)
        g = _random_graph(rng)
        names = list(g.tensors)
        persistent = tuple(
            names[int(rng.integers(0, len(names)))] for _ in range(n_pers)
        )
        plan = memory.plan_memory(g, persistent=persistent)
        assert plan.check_no_overlap()
        last = len(g.nodes) - 1
        for t in set(persistent):
            a = plan.allocations[t]
            assert (a.start, a.end) == (0, last)
        lb = memory.peak_lower_bound(g, persistent=persistent)
        total = sum(
            (max(g.tensors[t].bytes, 1) + 15) // 16 * 16 for t in plan.allocations
        )
        assert lb <= plan.peak <= total

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_property_alias_shares_allocation(self, seed):
        """An aliased output (in-place cache update) maps onto the exact
        allocation record of its source."""
        rng = np.random.default_rng(seed)
        g = _random_graph(rng)
        # pretend the graph output updates the input in place
        plan = memory.plan_memory(
            g, persistent=("in",), aliases={g.outputs[0]: "in"}
        )
        assert plan.check_no_overlap()
        assert plan.allocations[g.outputs[0]] == plan.allocations["in"]


class TestISqrtProperties:
    @given(v=st.integers(0, 2**31 - 1))
    @settings(max_examples=300, deadline=None)
    def test_floor_sqrt(self, v):
        got = int(iln.isqrt(jnp.int32(v)))
        want = max(1, int(np.floor(np.sqrt(v))))
        assert got == want


class TestRequantizeProperties:
    @given(
        acc=st.integers(-(1 << 25), (1 << 25) - 1),
        mult=st.integers(1, MULT_MAX),
        shift=st.integers(SHIFT_MIN, SHIFT_MAX),
    )
    @settings(max_examples=300, deadline=None)
    def test_bit_exact_vs_python_int(self, acc, mult, shift):
        got = int(requantize(jnp.int32(acc), mult, shift))
        assert got == _requant_gold(acc, mult, shift)

    @given(
        acc=st.integers(-(1 << 25), (1 << 25) - 1),
        mult=st.integers(1, MULT_MAX),
        shift=st.integers(SHIFT_MIN, SHIFT_MAX),
    )
    @settings(max_examples=200, deadline=None)
    def test_wide_matches_float(self, acc, mult, shift):
        got = int(requantize_wide(jnp.int32(acc), mult, shift, out_bits=31))
        gold = (acc * mult + (1 << (shift - 1))) >> shift
        gold = int(np.clip(gold, -(1 << 30), (1 << 30) - 1))
        assert got == gold

    @given(x=st.integers(-(1 << 29), (1 << 29)), s=st.integers(1, 20))
    @settings(max_examples=200, deadline=None)
    def test_rounding_shift_matches_python(self, x, s):
        got = int(rounding_rshift(jnp.int32(x), s))
        assert got == (x + (1 << (s - 1))) >> s


class TestBlockAllocatorProperties:
    @given(
        n_blocks=st.integers(1, 24),
        ops=st.lists(
            st.tuples(st.integers(0, 5), st.integers(0, 3), st.booleans()),
            max_size=40,
        ),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_no_block_owned_twice_and_frees_return(self, n_blocks, ops):
        """Random allocate/free traffic from interleaved owners: no
        physical block is ever owned by two live slots, the scratch block
        is never issued, failed allocations mutate nothing, and freed
        blocks genuinely return to the pool."""
        from repro.deploy.paging import (
            SCRATCH_BLOCK,
            BlockAllocator,
            PoolExhausted,
        )

        alloc = BlockAllocator(n_blocks)
        held: dict[int, list[int]] = {}
        for owner, n, do_free in ops:
            if do_free and held.get(owner):
                alloc.free(held.pop(owner))
            else:
                before = alloc.n_free
                try:
                    got = alloc.allocate(n, owner=owner)
                except PoolExhausted:
                    assert alloc.n_free == before  # all-or-nothing
                    continue
                held.setdefault(owner, []).extend(got)
            live = [b for blocks in held.values() for b in blocks]
            assert len(live) == len(set(live))  # no double ownership
            assert SCRATCH_BLOCK not in live
            assert all(1 <= b <= n_blocks for b in live)
            assert alloc.n_free + len(live) == n_blocks  # conservation
        for blocks in held.values():
            alloc.free(blocks)
        assert alloc.n_free == n_blocks  # everything returned

    @given(
        n_blocks=st.integers(1, 16),
        ops=st.lists(
            st.tuples(st.integers(0, 3),  # holder id
                      st.sampled_from(["alloc", "fork", "cow", "free"]),
                      st.integers(0, 3)),  # count / source holder / pick
            max_size=60,
        ),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_fork_cow_free_interleavings(self, n_blocks, ops):
        """Random fork/cow/free traffic (the prefix-cache access
        pattern): the allocator's refcount for every live block always
        equals the references the model actually holds, conservation
        (``n_free + live == n_blocks``) holds after every operation,
        failed cow/alloc mutate nothing, and draining every reference —
        shared blocks freed once per holder — returns the whole pool
        without ever double-freeing."""
        from repro.deploy.paging import BlockAllocator, PoolExhausted

        alloc = BlockAllocator(n_blocks)
        held: dict[int, list[int]] = {}  # holder -> refs (list = multiset)

        def refs_of(b):
            return sum(blocks.count(b) for blocks in held.values())

        for holder, op, k in ops:
            if op == "alloc":
                before = alloc.n_free
                try:
                    got = alloc.allocate(k, owner=holder)
                except PoolExhausted:
                    assert alloc.n_free == before  # all-or-nothing
                    continue
                held.setdefault(holder, []).extend(got)
            elif op == "fork":
                src = held.get(k)
                if not src:
                    continue
                take = src[: max(1, holder)]
                assert alloc.fork(take) == take
                held.setdefault(holder, []).extend(take)
            elif op == "cow":
                mine = held.get(holder)
                if not mine:
                    continue
                b = mine[k % len(mine)]
                before = alloc.n_free
                shared = refs_of(b) > 1
                try:
                    fresh, copied = alloc.cow(b, owner=holder)
                except PoolExhausted:
                    # loud and mutation-free: the share survives intact
                    assert alloc.n_free == before
                    assert alloc.refcount(b) == refs_of(b)
                    continue
                assert copied == shared
                if copied:
                    mine[mine.index(b)] = fresh  # one ref moved over
                else:
                    assert fresh == b  # exclusive: write in place
            elif op == "free":
                if held.get(holder):
                    alloc.free(held.pop(holder))
            live = {b for blocks in held.values() for b in blocks}
            assert alloc.n_free + len(live) == n_blocks  # conservation
            for b in live:
                assert alloc.refcount(b) == refs_of(b) >= 1
            assert alloc.n_shared == sum(refs_of(b) > 1 for b in live)
        for blocks in held.values():
            alloc.free(blocks)  # would raise on any double-free
        assert alloc.n_free == n_blocks


class TestPagedPlanProperties:
    @given(
        seq=st.sampled_from([4, 8]),
        block=st.sampled_from([2, 4, 8]),
        blocks=st.integers(2, 9),
    )
    @settings(max_examples=8, deadline=None)
    def test_property_pool_offsets_identical_across_pair(self, seq, block, blocks):
        """Any paged geometry: the prefill and decode schedules allocate
        every pool tensor at the same static offset/size (the linked
        plans literally share one region), and validate() holds."""
        from repro.configs import get_config, reduced
        from repro.deploy.lowering import lower_decoder

        cfg = reduced(get_config("olmo-1b"))
        pair = lower_decoder(cfg, seq, max_len=seq + block * 2,
                             kv_block_size=block, kv_blocks=blocks)
        assert pair.paged
        names = pair.kv_tensors
        assert names  # pools exist
        assert not memory.shared_persistent_offsets(
            pair.prefill.tensors, pair.decode.tensors, names
        )
        # pools are stacked contiguously from offset 0 (sorted-name order)
        offsets = sorted(pair.prefill.tensors[n].offset for n in names)
        assert offsets[0] == 0

    @given(
        depths=st.lists(st.integers(0, 11), min_size=2, max_size=3),
        seed=st.integers(0, 500),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_paged_runners_bit_exact_vs_dense(self, depths, seed):
        """Random per-slot depths and block tables: the paged
        ``cache_write`` + ``attn_cached`` runner pair computes exactly
        the dense runners' ints (the block-table gather is a layout
        change, not an arithmetic one)."""
        import jax

        from repro.core.heterogeneous import DEFAULT_TABLE, Backend, Engine, OpDesc
        from repro.deploy.paging import SCRATCH_BLOCK

        hkv, heads, d, block, max_len = 2, 4, 8, 4, 12
        b = len(depths)
        rng = np.random.default_rng(seed)

        def rand8(*shape):
            return jnp.asarray(rng.integers(-128, 128, shape), jnp.int8)

        def cluster(kind):
            return DEFAULT_TABLE._lookup(kind, Engine.CLUSTER, Backend.W8A8)

        # dense cache with each slot's first `depth` rows populated
        dense_k = np.zeros((b, hkv, max_len, d), np.int8)
        dense_v = np.zeros((b, hkv, max_len, d), np.int8)
        nblk = max_len // block
        pool_k = np.zeros((b * nblk + 1, hkv, block, d), np.int8)
        pool_v = np.zeros_like(pool_k)
        table = np.full((b, nblk), SCRATCH_BLOCK, np.int32)
        next_free = 1
        for i, depth in enumerate(depths):
            rows_k = rng.integers(-128, 128, (hkv, depth, d))
            rows_v = rng.integers(-128, 128, (hkv, depth, d))
            dense_k[i, :, :depth] = rows_k
            dense_v[i, :, :depth] = rows_v
            # blocks cover the append target row `depth` too — the session
            # allocates the crossed-into block before dispatching
            for blk_i in range(-(-(depth + 1) // block)):
                table[i, blk_i] = next_free
                lo = blk_i * block
                pool_k[next_free, :, : max(0, min(depth - lo, block))] = (
                    rows_k[:, lo : lo + block])
                pool_v[next_free, :, : max(0, min(depth - lo, block))] = (
                    rows_v[:, lo : lo + block])
                next_free += 1

        pos = jnp.asarray(depths, jnp.int32)
        kv_new = rand8(b, 1, hkv * d)
        q_new = rand8(b, 1, heads * d)

        # cache_write: dense row-append vs paged block scatter
        dk = cluster("cache_write")(kv_new, jnp.asarray(dense_k), pos,
                                    kv_heads=hkv, head_dim=d, max_len=max_len)
        pk = cluster("cache_write_paged")(kv_new, jnp.asarray(pool_k), pos,
                                          jnp.asarray(table), None,
                                          kv_heads=hkv, head_dim=d,
                                          block_size=block)
        # compare through each slot's logical view (gather its blocks)
        gathered = np.asarray(pk)[table].transpose(0, 2, 1, 3, 4).reshape(
            b, hkv, max_len, d)
        for i, depth in enumerate(depths):
            np.testing.assert_array_equal(
                np.asarray(dk)[i, :, : depth + 1], gathered[i, :, : depth + 1])

        # attn: dense cache-masked vs paged gathered, same ints
        dv = cluster("cache_write")(kv_new, jnp.asarray(dense_v), pos,
                                    kv_heads=hkv, head_dim=d, max_len=max_len)
        pv = cluster("cache_write_paged")(kv_new, jnp.asarray(pool_v), pos,
                                          jnp.asarray(table), None,
                                          kv_heads=hkv, head_dim=d,
                                          block_size=block)
        dense_out = cluster("attn_cached")(
            q_new, dk, dv, pos, heads=heads, head_dim=d,
            s_act=0.05, s_out=0.05, block_k=2048)
        paged_out = cluster("attn_paged")(
            q_new, pk, pv, pos, jnp.asarray(table), heads=heads,
            kv_heads=hkv, head_dim=d, s_act=0.05, s_out=0.05, block_k=2048)
        np.testing.assert_array_equal(np.asarray(dense_out),
                                      np.asarray(paged_out))


class TestItamaxProperties:
    @given(data=st.data())
    @settings(max_examples=30, deadline=None)
    def test_property_monotone(self, data):
        """Larger logit -> no smaller attention weight (within a row)."""
        n = data.draw(st.integers(8, 96))
        row = data.draw(
            st.lists(st.integers(-128, 127), min_size=n, max_size=n)
        )
        x = jnp.asarray([row], jnp.int8)
        a = np.asarray(im.itamax_rowwise(x))[0]
        order = np.argsort(row, kind="stable")
        assert (np.diff(a[order]) >= 0).all()


class TestFusionProperties:
    @given(
        seq=st.sampled_from([4, 8]),
        paged=st.booleans(),
        min_nodes=st.integers(2, 12),
        phase=st.sampled_from(["prefill", "decode"]),
    )
    @settings(max_examples=16, deadline=None)
    def test_property_fusion_respects_engines_and_kv_writes(
            self, seq, paged, min_nodes, phase):
        """Any geometry, any fusion boundary, both schedule phases:
        region fusion never mixes engines inside a body, never hides a
        KV persistent-tensor write or cache-write barrier, never nests,
        preserves the flattened schedule order exactly, and the result
        still validates."""
        from repro.configs import get_config, reduced
        from repro.deploy import patterns
        from repro.deploy.lowering import lower_decoder

        cfg = reduced(get_config("olmo-1b"))
        kw = dict(kv_block_size=4, kv_blocks=8) if paged else {}
        pair = lower_decoder(cfg, seq, max_len=seq + 8, fuse=False, **kw)
        plan = getattr(pair, phase)
        fused = patterns.fuse_regions(plan, min_nodes=min_nodes)
        fused.validate()
        kv_writes = {cout for _, cout in plan.kv_state}
        assert [n.name for n in fused.flat_nodes()] == \
            [n.name for n in plan.nodes]
        for n in fused.nodes:
            if not n.fused:
                continue
            assert len(n.body) >= max(min_nodes, 2)
            assert {b.engine for b in n.body} == {n.engine}
            for b in n.body:
                assert not b.fused  # no nesting
                assert b.kind not in patterns.FUSION_BARRIERS
                assert not (set(b.outputs) & kv_writes)


def _plan_from_graph(g, mem):
    """Assemble a synthetic DeploymentPlan from a scheduled graph + its
    static memory layout (shared helper for the verifier properties).
    ``g`` must already have every sink in ``g.outputs`` (see
    :func:`_mark_sinks`) so the allocator and the verifier agree on
    output lifetimes."""
    from repro.deploy.patterns import KIND_BY_OP
    from repro.deploy.plan import DeploymentPlan, PlanNode, TensorSpec

    nodes = [
        PlanNode(name=n.name, op=n.op, kind=KIND_BY_OP[n.op],
                 engine="cluster", inputs=tuple(n.inputs),
                 outputs=tuple(n.outputs), attrs=dict(n.attrs))
        for n in g.nodes
    ]
    tensors = {}
    for name, ti in g.tensors.items():
        a = mem.allocations.get(name)
        tensors[name] = TensorSpec(
            name=name, shape=tuple(ti.shape), dtype=ti.dtype,
            offset=None if a is None else a.offset,
            size=0 if a is None else a.size,
        )
    return DeploymentPlan(
        arch="synthetic", seq_len=1, granule=64, head_by_head=False,
        quant={}, nodes=nodes, tensors=tensors, inputs=tuple(g.inputs),
        outputs=tuple(g.outputs),
        schedule=tuple(n.name for n in nodes), memory_peak=mem.peak,
    )


def _mark_sinks(g):
    """Promote every never-consumed tensor to a graph output, so the
    allocator keeps it live to the end of the schedule — exactly the
    lifetime contract the verifier enforces on plan outputs (and no dead
    intermediates remain to trip the DF002 lint)."""
    consumed = {t for n in g.nodes for t in n.inputs}
    g.outputs = [t for t in g.tensors
                 if t not in consumed and t not in g.inputs] or g.outputs
    return g


class TestVerifierProperties:
    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_valid_random_schedules_verify_clean(self, seed):
        """Soundness of the verifier's clean direction: any topologically
        scheduled graph with a correct static layout must produce ZERO
        diagnostics — the lint never cries wolf on valid plans."""
        from repro.deploy.verify import verify_plan

        g = _mark_sinks(_random_graph(np.random.default_rng(seed)))
        plan = _plan_from_graph(g, memory.plan_memory(g))
        assert verify_plan(plan) == []

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_forced_aliasing_is_flagged(self, seed):
        """Completeness on the memory-race class: force any two co-live
        allocations onto the same offset and MEM001 must fire."""
        from dataclasses import replace

        from repro.deploy.verify import verify_plan

        g = _mark_sinks(_random_graph(np.random.default_rng(seed)))
        mem = memory.plan_memory(g)
        plan = _plan_from_graph(g, mem)
        allocs = list(dict.fromkeys(mem.allocations.values()))
        colive = next(
            ((a, b) for i, a in enumerate(allocs) for b in allocs[i + 1:]
             if not (a.end < b.start or b.end < a.start)
             and a.offset != b.offset),
            None,
        )
        if colive is None:
            return  # degenerate chain graph: nothing is ever co-live
        a, b = colive
        plan.tensors[a.tensor] = replace(plan.tensors[a.tensor],
                                         offset=b.offset)
        rules = {d.rule for d in verify_plan(plan)
                 if d.severity == "error"}
        assert "MEM001" in rules

    @given(seed=st.integers(0, 10_000), drop=st.integers(0, 30))
    @settings(max_examples=25, deadline=None)
    def test_property_dropped_producer_is_flagged(self, seed, drop):
        """Completeness on the dataflow class: delete any node whose
        output is consumed downstream and DF001 (or DF003 for a dropped
        output producer) must fire."""
        from repro.deploy.verify import verify_plan

        g = _mark_sinks(_random_graph(np.random.default_rng(seed)))
        plan = _plan_from_graph(g, memory.plan_memory(g))
        consumed = {t for n in plan.nodes for t in n.inputs}
        keep = set(plan.outputs)
        victims = [i for i, n in enumerate(plan.nodes)
                   if set(n.outputs) & (consumed | keep)]
        i = victims[drop % len(victims)]
        del plan.nodes[i]
        plan.schedule = tuple(n.name for n in plan.nodes)
        rules = {d.rule for d in verify_plan(plan)
                 if d.severity == "error"}
        assert rules & {"DF001", "DF003"}


def _sched_handle(rid, priority, ttft_slo_ms, deadline_ms, arrival_t):
    """Minimal object satisfying the Scheduler handle contract."""
    from repro.deploy.serving.scheduler import effective_deadline

    class H:
        pass

    h = H()
    h.rid = rid
    h.priority = priority
    h.ttft_slo_ms = ttft_slo_ms
    h.deadline_ms = deadline_ms
    h.arrival_t = arrival_t
    h.deadline_t = (None if deadline_ms is None
                    else arrival_t + deadline_ms / 1e3)
    h.admit_deadline_t = effective_deadline(arrival_t, ttft_slo_ms,
                                            deadline_ms)
    return h


_handle_st = st.builds(
    _sched_handle,
    rid=st.integers(0, 10_000),
    priority=st.integers(-5, 20),
    ttft_slo_ms=st.none() | st.floats(0.0, 1e5, allow_nan=False),
    deadline_ms=st.none() | st.floats(0.0, 1e5, allow_nan=False),
    arrival_t=st.floats(0.0, 1e4, allow_nan=False),
)


class TestSchedulerProperties:
    @given(hs=st.lists(_handle_st, min_size=2, max_size=12,
                       unique_by=lambda h: h.rid),
           now=st.floats(0.0, 2e4, allow_nan=False))
    @settings(max_examples=80, deadline=None)
    def test_property_key_is_strict_total_order(self, hs, now):
        """The PriorityDeadline sort key never ties on distinct rids
        (the rid tiebreak makes it a strict total order), and popping
        drains the queue in exactly sorted-key order."""
        from repro.deploy.serving.scheduler import PriorityDeadline

        s = PriorityDeadline(aging_s=3.0)
        for h in hs:
            s.add(h, now)
        keys = [s.key(h, now) for h in hs]
        assert len(set(keys)) == len(keys)
        want = [h.rid for h in sorted(hs, key=lambda h: s.key(h, now))]
        got = [s.pop(now).rid for _ in range(len(hs))]
        assert got == want and s.pop(now) is None

    @given(hs=st.lists(_handle_st, min_size=2, max_size=12,
                       unique_by=lambda h: h.rid))
    @settings(max_examples=80, deadline=None)
    def test_property_order_matches_contract_when_aging_is_off(self, hs):
        """With aging effectively disabled, the admitted order is exactly
        lexicographic (priority, effective deadline, rid) — the
        documented scheduler contract."""
        from repro.deploy.serving.scheduler import PriorityDeadline

        s = PriorityDeadline(aging_s=1e12)
        now = max(h.arrival_t for h in hs)
        for h in hs:
            s.add(h, now)
        want = [h.rid for h in
                sorted(hs, key=lambda h: (h.priority, h.admit_deadline_t,
                                          h.rid))]
        assert [s.pop(now).rid for _ in range(len(hs))] == want

    @given(old_priority=st.integers(0, 20),
           fresh_priority=st.integers(0, 20),
           aging_s=st.floats(0.1, 60.0, allow_nan=False))
    @settings(max_examples=80, deadline=None)
    def test_property_aging_guarantees_starvation_freedom(
            self, old_priority, fresh_priority, aging_s):
        """Any queued request eventually outranks ANY fresh arrival: by
        ``(old_priority - fresh_priority + 1) * aging_s`` seconds of
        waiting, the aged key is strictly smaller even against a fresh
        request with a tight (earlier-deadline) SLO."""
        from repro.deploy.serving.scheduler import PriorityDeadline

        s = PriorityDeadline(aging_s=aging_s)
        old = _sched_handle(0, old_priority, None, None, arrival_t=0.0)
        wait = (max(0, old_priority - fresh_priority) + 1) * aging_s
        now = wait * 1.0000001  # strictly past the promotion boundary
        fresh = _sched_handle(1, fresh_priority, 1.0, None, arrival_t=now)
        assert s.key(old, now) < s.key(fresh, now)

"""Hypothesis property tests (all modules), gathered behind one guard.

The ``[test]`` extra installs hypothesis; where it is missing this module
skips at collection (``pytest.importorskip``) and the deterministic suites
keep running — the suite degrades gracefully instead of breaking
collection.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")

import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ilayernorm as iln
from repro.core import itamax as im
from repro.deploy import memory, tiler
from repro.deploy.graph import Graph
from repro.quant.qparams import (
    MULT_MAX,
    SHIFT_MAX,
    SHIFT_MIN,
    requantize,
    requantize_wide,
    rounding_rshift,
)


def _requant_gold(acc, mult, shift, zp=0):
    """Arbitrary-precision (python int) reference of requantize."""
    out = (int(acc) * int(mult) + (1 << (shift - 1))) >> shift
    return int(np.clip(out + zp, -128, 127))


class TestTilerProperties:
    @given(
        m=st.integers(1, 2048), n=st.integers(1, 2048), k=st.integers(1, 2048)
    )
    @settings(max_examples=60, deadline=None)
    def test_property_always_feasible(self, m, n, k):
        t = tiler.solve_gemm_tiling(m, n, k)
        assert t.l1_bytes <= tiler.ITA_L1_BYTES
        assert t.useful_ops == 2 * m * n * k


def _random_graph(rng) -> Graph:
    """Random branching DAG over 2-D int8 tensors (shared helper)."""
    g = Graph()
    live = [g.add_tensor("in", (int(rng.integers(1, 64)), 32))]
    g.inputs.append("in")
    for i in range(int(rng.integers(2, 25))):
        src = [live[int(rng.integers(0, len(live)))]]
        if rng.random() < 0.4 and len(live) > 1:
            src.append(live[int(rng.integers(0, len(live)))])
        out = g.add_tensor(f"t{i}", (int(rng.integers(1, 64)), 32))
        g.add_node("Add" if len(src) > 1 else "LayerNorm", src, [out],
                   dims=g.tensors[out].shape)
        live.append(out)
    g.outputs.append(live[-1])
    return g


class TestMemoryPlannerProperties:
    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_random_graphs_no_overlap(self, seed):
        """Random branching DAGs: planner must never alias live tensors."""
        g = _random_graph(np.random.default_rng(seed))
        plan = memory.plan_memory(g)
        assert plan.check_no_overlap()
        assert plan.peak >= memory.peak_lower_bound(g)

    @given(seed=st.integers(0, 10_000), n_pers=st.integers(1, 6))
    @settings(max_examples=25, deadline=None)
    def test_property_persistent_no_overlap_and_bounded(self, seed, n_pers):
        """KV-cache-style persistent tensors (whole-schedule lifetimes):
        no aliasing with any transient, peak bracketed by the lower bound
        and the everything-is-live upper bound."""
        rng = np.random.default_rng(seed)
        g = _random_graph(rng)
        names = list(g.tensors)
        persistent = tuple(
            names[int(rng.integers(0, len(names)))] for _ in range(n_pers)
        )
        plan = memory.plan_memory(g, persistent=persistent)
        assert plan.check_no_overlap()
        last = len(g.nodes) - 1
        for t in set(persistent):
            a = plan.allocations[t]
            assert (a.start, a.end) == (0, last)
        lb = memory.peak_lower_bound(g, persistent=persistent)
        total = sum(
            (max(g.tensors[t].bytes, 1) + 15) // 16 * 16 for t in plan.allocations
        )
        assert lb <= plan.peak <= total

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_property_alias_shares_allocation(self, seed):
        """An aliased output (in-place cache update) maps onto the exact
        allocation record of its source."""
        rng = np.random.default_rng(seed)
        g = _random_graph(rng)
        # pretend the graph output updates the input in place
        plan = memory.plan_memory(
            g, persistent=("in",), aliases={g.outputs[0]: "in"}
        )
        assert plan.check_no_overlap()
        assert plan.allocations[g.outputs[0]] == plan.allocations["in"]


class TestISqrtProperties:
    @given(v=st.integers(0, 2**31 - 1))
    @settings(max_examples=300, deadline=None)
    def test_floor_sqrt(self, v):
        got = int(iln.isqrt(jnp.int32(v)))
        want = max(1, int(np.floor(np.sqrt(v))))
        assert got == want


class TestRequantizeProperties:
    @given(
        acc=st.integers(-(1 << 25), (1 << 25) - 1),
        mult=st.integers(1, MULT_MAX),
        shift=st.integers(SHIFT_MIN, SHIFT_MAX),
    )
    @settings(max_examples=300, deadline=None)
    def test_bit_exact_vs_python_int(self, acc, mult, shift):
        got = int(requantize(jnp.int32(acc), mult, shift))
        assert got == _requant_gold(acc, mult, shift)

    @given(
        acc=st.integers(-(1 << 25), (1 << 25) - 1),
        mult=st.integers(1, MULT_MAX),
        shift=st.integers(SHIFT_MIN, SHIFT_MAX),
    )
    @settings(max_examples=200, deadline=None)
    def test_wide_matches_float(self, acc, mult, shift):
        got = int(requantize_wide(jnp.int32(acc), mult, shift, out_bits=31))
        gold = (acc * mult + (1 << (shift - 1))) >> shift
        gold = int(np.clip(gold, -(1 << 30), (1 << 30) - 1))
        assert got == gold

    @given(x=st.integers(-(1 << 29), (1 << 29)), s=st.integers(1, 20))
    @settings(max_examples=200, deadline=None)
    def test_rounding_shift_matches_python(self, x, s):
        got = int(rounding_rshift(jnp.int32(x), s))
        assert got == (x + (1 << (s - 1))) >> s


class TestItamaxProperties:
    @given(data=st.data())
    @settings(max_examples=30, deadline=None)
    def test_property_monotone(self, data):
        """Larger logit -> no smaller attention weight (within a row)."""
        n = data.draw(st.integers(8, 96))
        row = data.draw(
            st.lists(st.integers(-128, 127), min_size=n, max_size=n)
        )
        x = jnp.asarray([row], jnp.int8)
        a = np.asarray(im.itamax_rowwise(x))[0]
        order = np.argsort(row, kind="stable")
        assert (np.diff(a[order]) >= 0).all()

"""PTQ calibration: calibrated scales must beat the default grids."""

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import encoder as EN
from repro.quant.ptq import calibrate_encoder, quantization_error


def test_calibration_improves_fidelity():
    cfg = reduced(get_config("mobilebert"))
    key = jax.random.PRNGKey(0)
    params = EN.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": tokens}
    fl = EN.forward(cfg, params, batch)

    default = quantization_error(fl, EN.forward_w8a8(cfg, EN.quantize_params(cfg, params), batch))
    q = calibrate_encoder(cfg, params, [batch])
    calibrated = quantization_error(
        fl, EN.forward_w8a8(cfg, EN.quantize_params(cfg, params, q), batch, q=q)
    )
    assert calibrated["cosine"] > default["cosine"] + 0.2
    assert calibrated["rel_err"] < default["rel_err"]
    assert calibrated["argmax_agreement"] > default["argmax_agreement"]
    # calibrated integer path tracks float logits meaningfully even on a
    # random-init model (the adversarial case for PTQ)
    assert calibrated["cosine"] > 0.6


def test_calibrated_scales_within_gelu_guard():
    """Calibration must respect the i-GeLU int32-safety floor."""
    from repro.core.igelu import MIN_GELU_SCALE

    cfg = reduced(get_config("dinov2-small"))
    key = jax.random.PRNGKey(1)
    params = EN.init_params(cfg, key)
    patches = jax.random.normal(key, (2, 32, cfg.d_model))
    q = calibrate_encoder(cfg, params, [{"patches": patches}])
    assert q.s_act >= MIN_GELU_SCALE
    assert q.s_res > 0 and q.s_w > 0

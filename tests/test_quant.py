"""Unit + property tests for the fixed-point requantization core."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.qparams import (
    MULT_MAX,
    SHIFT_MAX,
    SHIFT_MIN,
    make_qparams,
    quantize_array,
    quantize_multiplier,
    quantize_weight_per_channel,
    requantize,
    rounding_rshift,
)


def _requant_gold(acc, mult, shift, zp=0):
    """Arbitrary-precision (python int) reference of requantize."""
    out = (int(acc) * int(mult) + (1 << (shift - 1))) >> shift
    return int(np.clip(out + zp, -128, 127))


class TestQuantizeMultiplier:
    @pytest.mark.parametrize("m", [1e-4, 3.7e-3, 0.02, 0.13, 0.5, 1.0, 7.3, 31.9])
    def test_representation_error(self, m):
        mult, shift = quantize_multiplier(m)
        assert 0 <= mult <= MULT_MAX
        assert SHIFT_MIN <= shift <= SHIFT_MAX
        rel = abs(mult * 2.0**-shift - m) / m
        assert rel < 2e-4, (m, mult, shift, rel)

    def test_zero(self):
        assert quantize_multiplier(0.0)[0] == 0


class TestRequantize:
    def test_sampled_vs_python_int(self):
        rng = np.random.default_rng(0)
        for _ in range(64):
            acc = int(rng.integers(-(1 << 25), 1 << 25))
            mult = int(rng.integers(1, MULT_MAX))
            shift = int(rng.integers(SHIFT_MIN, SHIFT_MAX + 1))
            assert int(requantize(jnp.int32(acc), mult, shift)) == _requant_gold(
                acc, mult, shift
            )

    def test_vectorized(self):
        accs = jnp.arange(-1000, 1000, 7, dtype=jnp.int32) * 1001
        out = requantize(accs, 12345, 20)
        gold = np.array([_requant_gold(int(a), 12345, 20) for a in np.asarray(accs)])
        np.testing.assert_array_equal(np.asarray(out), gold)

    def test_end_to_end_scaling(self):
        # quantize float -> requant == float multiply within 1 LSB
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64,)).astype(np.float32) * 1000
        acc = jnp.asarray(np.round(x), jnp.int32)
        s_in, s_out = 0.01, 0.37
        qp = make_qparams(s_in, 1.0, s_out)
        got = np.asarray(requantize(acc, qp.mult, qp.shift), np.int32)
        want = np.clip(np.round(np.round(x) * s_in / s_out), -128, 127)
        assert np.max(np.abs(got - want)) <= 1


class TestRoundingShift:
    def test_sampled_matches_python(self):
        rng = np.random.default_rng(1)
        for _ in range(64):
            x = int(rng.integers(-(1 << 29), (1 << 29) + 1))
            s = int(rng.integers(1, 21))
            assert int(rounding_rshift(jnp.int32(x), s)) == (x + (1 << (s - 1))) >> s


class TestWeightQuant:
    def test_per_channel_roundtrip(self):
        rng = np.random.default_rng(1)
        w = rng.normal(size=(32, 16)).astype(np.float32)
        q, s = quantize_weight_per_channel(jnp.asarray(w), axis=1)
        deq = np.asarray(q, np.float32) * np.asarray(s)
        err = np.abs(deq - w)
        assert err.max() <= np.abs(w).max() / 127 * 0.51 + 1e-6

    def test_quantize_array_clip(self):
        x = jnp.asarray([-1e9, 0.0, 1e9])
        q = quantize_array(x, 1.0)
        assert list(np.asarray(q)) == [-128, 0, 127]

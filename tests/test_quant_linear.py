"""Tests for the quantized linear layer (ITA GEMM mode, XLA path)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant_linear as ql
from repro.quant.qparams import quantize_array, quantize_weight_per_channel


def _setup(rng, m, k, n, act, per_channel=False):
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32) / np.sqrt(k)
    b = rng.normal(size=(n,)).astype(np.float32) * 0.1
    s_in = float(np.abs(x).max() / 127)
    if per_channel:
        w_q, s_w = quantize_weight_per_channel(jnp.asarray(w), axis=1)
        s_w_np = np.asarray(s_w).reshape(-1)
    else:
        s_w_np = np.abs(w).max() / 127
        w_q = quantize_array(jnp.asarray(w), float(s_w_np), -127, 127)
    y_ref = np.asarray(ql.linear_f32(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act))
    s_out = float(np.abs(y_ref).max() / 127) + 1e-9
    s_pre = float(np.abs(np.asarray(x @ w + b)).max() / 127) + 1e-9
    bias_q = jnp.asarray(np.round(b / (s_in * s_w_np)), jnp.int32)
    p = ql.make_qlinear_params(s_in, s_w_np, s_out, act, s_preact=s_pre)
    x_q = quantize_array(jnp.asarray(x), s_in)
    return x_q, w_q, bias_q, p, y_ref, s_out


class TestQLinear:
    @pytest.mark.parametrize("act", [ql.ACT_IDENTITY, ql.ACT_RELU, ql.ACT_GELU])
    @pytest.mark.parametrize("per_channel", [False, True])
    def test_matches_float(self, act, per_channel):
        rng = np.random.default_rng(0)
        x_q, w_q, bias_q, p, y_ref, s_out = _setup(rng, 32, 128, 64, act, per_channel)
        y_q = np.asarray(ql.qlinear_i8(x_q, w_q, bias_q, p), np.float32) * s_out
        # int8 x int8 GEMM: error budget ~ input-quant noise propagated
        tol = 6 * s_out + 0.02 * np.abs(y_ref).max()
        assert np.max(np.abs(y_q - y_ref)) < tol, np.max(np.abs(y_q - y_ref))

    def test_no_bias(self):
        rng = np.random.default_rng(1)
        x_q, w_q, _, p, _, s_out = _setup(rng, 8, 64, 32, ql.ACT_IDENTITY)
        y = ql.qlinear_i8(x_q, w_q, None, p)
        assert y.dtype == jnp.int8 and y.shape == (8, 32)

    def test_batched_input(self):
        rng = np.random.default_rng(2)
        x_q, w_q, bias_q, p, _, _ = _setup(rng, 4, 64, 32, ql.ACT_IDENTITY)
        x3 = jnp.broadcast_to(x_q, (5, 4, 64))
        y3 = ql.qlinear_i8(x3, w_q, bias_q, p)
        y1 = ql.qlinear_i8(x_q, w_q, bias_q, p)
        np.testing.assert_array_equal(np.asarray(y3[2]), np.asarray(y1))

    def test_relu_nonnegative(self):
        rng = np.random.default_rng(3)
        x_q, w_q, bias_q, p, _, _ = _setup(rng, 16, 64, 32, ql.ACT_RELU)
        y = np.asarray(ql.qlinear_i8(x_q, w_q, bias_q, p))
        assert (y >= 0).all()

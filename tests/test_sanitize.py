"""Concurrency & KV-lifetime sanitizer (ISSUE 10).

Acceptance contract: the static lock-order + affinity lints run clean on
``src/repro/deploy`` itself; every sanitizer rule id is demonstrated by
a mutation test (a seeded deadlock, an inverted acquisition, a skipped
COW, a double free, a dropped refcount — each caught with its exact
``LOCK*`` / ``AFF*`` / ``BLK*`` id); the bounded interleaving model
checks verify the clean fork/COW/free and scheduler cancel protocols and
catch each seeded protocol bug; and the full serving stack (session,
engine, ``AsyncEngine`` under thread stress) runs with
``REPRO_SANITIZE=1`` producing zero findings.
"""

import os
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.deploy import api
from repro.deploy import sanitize as S
from repro.deploy.engine import Engine
from repro.deploy.paging import BlockAllocator
from repro.deploy.sanitize import (
    SanitizerDiagnostic,
    SanitizerError,
    affinity_report,
    check_block_interleavings,
    check_interleavings,
    check_scheduler_interleavings,
    lint_affinity,
    lint_lock_order,
)
from repro.models import transformer as T

SEQ = 8
MAX_LEN = 24


@pytest.fixture(autouse=True)
def _clean_lockdep():
    """Each test starts with an empty observed-order graph / findings."""
    S.reset_runtime()
    yield
    S.reset_runtime()


@pytest.fixture
def sanitize_on(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")


@pytest.fixture(scope="module")
def olmo():
    cfg = reduced(get_config("olmo-1b"))
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    return cfg, params


@pytest.fixture(scope="module")
def paged_model(olmo):
    return api.compile(olmo[0], backend="w8a8", seq_len=SEQ, max_len=MAX_LEN,
                       use_cache=False, kv_block_size=4, kv_blocks=14)


def _prompts(cfg, n, *, lengths=(SEQ, SEQ + 2), seed=0):
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    return [
        [int(t) for t in jax.random.randint(jax.random.fold_in(key, i),
                                            (lengths[i % len(lengths)],), 0,
                                            cfg.vocab, jnp.int32)]
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# static lock-order lint
# ---------------------------------------------------------------------------


class TestStaticLockLint:
    def test_repo_lints_clean(self):
        assert lint_lock_order() == []

    def test_lock001_two_lock_cycle(self, tmp_path):
        f = tmp_path / "cycle.py"
        f.write_text(
            "import threading\n"
            "class Duo:\n"
            "    def __init__(self):\n"
            "        self.a = threading.Lock()\n"
            "        self.b = threading.Lock()\n"
            "    def ab(self):\n"
            "        with self.a:\n"
            "            with self.b:\n"
            "                pass\n"
            "    def ba(self):\n"
            "        with self.b:\n"
            "            with self.a:\n"
            "                pass\n")
        diags = lint_lock_order([str(f)])
        assert {d.rule for d in diags} == {"LOCK001"}

    def test_lock001_self_deadlock_through_call_graph(self, tmp_path):
        f = tmp_path / "selfdead.py"
        f.write_text(
            "import threading\n"
            "class SelfDeadlock:\n"
            "    def __init__(self):\n"
            "        self.m = threading.Lock()\n"
            "    def outer(self):\n"
            "        with self.m:\n"
            "            self.inner()\n"
            "    def inner(self):\n"
            "        with self.m:\n"
            "            pass\n")
        diags = lint_lock_order([str(f)])
        assert any(d.rule == "LOCK001" for d in diags)

    def test_lock001_not_raised_for_reentrant_self_edge(self, tmp_path):
        f = tmp_path / "reentrant.py"
        f.write_text(
            "import threading\n"
            "class Fine:\n"
            "    def __init__(self):\n"
            "        self.m = threading.RLock()\n"
            "    def outer(self):\n"
            "        with self.m:\n"
            "            self.inner()\n"
            "    def inner(self):\n"
            "        with self.m:\n"
            "            pass\n")
        assert lint_lock_order([str(f)]) == []

    def test_lock002_lattice_inversion(self, tmp_path):
        f = tmp_path / "lattice.py"
        f.write_text(
            "from repro.deploy.sanitize import make_condition, make_lock\n"
            "class Inverted:\n"
            "    def __init__(self):\n"
            "        self.lock = make_lock('engine.lock', reentrant=True)\n"
            "        self.cv = make_condition('serving.cv')\n"
            "    def bad(self):\n"
            "        with self.lock:\n"
            "            with self.cv:\n"
            "                pass\n")
        diags = lint_lock_order([str(f)])
        assert any(d.rule == "LOCK002" for d in diags)

    def test_lock004_static_wait_while_holding(self, tmp_path):
        f = tmp_path / "waithold.py"
        f.write_text(
            "from repro.deploy.sanitize import make_condition, make_lock\n"
            "class WaitsWhileHolding:\n"
            "    def __init__(self):\n"
            "        self.cv = make_condition('serving.cv')\n"
            "        self.lock = make_lock('engine.lock', reentrant=True)\n"
            "    def bad(self):\n"
            "        with self.cv:\n"
            "            with self.lock:\n"
            "                self.cv.wait()\n")
        diags = lint_lock_order([str(f)])
        assert any(d.rule == "LOCK004" for d in diags)

    def test_diagnostics_are_structured(self, tmp_path):
        f = tmp_path / "cycle.py"
        f.write_text(
            "import threading\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self.m = threading.Lock()\n"
            "    def f(self):\n"
            "        with self.m:\n"
            "            self.f2()\n"
            "    def f2(self):\n"
            "        with self.m:\n"
            "            pass\n")
        (d,) = lint_lock_order([str(f)])[:1]
        assert isinstance(d, SanitizerDiagnostic)
        assert d.severity == "error"
        assert "LOCK001" in d.format()


# ---------------------------------------------------------------------------
# thread-affinity lint (satellite: _affine coverage audit)
# ---------------------------------------------------------------------------


class TestAffinityLint:
    def test_session_lints_clean(self):
        assert lint_affinity() == []

    def test_every_known_mutator_is_classified_and_guarded(self):
        rep = affinity_report()
        need = {"prefill", "prefill_slot", "prefill_chunk", "prefill_chunks",
                "free_slot", "attach_prefix", "decode"}
        for m in need:
            assert rep[m]["mutating"], f"{m} not classified as mutating"
            assert rep[m]["guarded"], f"{m} does not call _affine"

    def test_aff001_on_unguarded_mutator(self, tmp_path):
        f = tmp_path / "unguarded.py"
        f.write_text(
            "class InferenceSession:\n"
            "    def _affine(self, method):\n"
            "        pass\n"
            "    def guarded(self, x):\n"
            "        self._affine('guarded')\n"
            "        self._pos = x\n"
            "    def unguarded(self, x):\n"
            "        self._pos = x\n"
            "    def reader(self):\n"
            "        return self._pos\n")
        diags = lint_affinity(path=str(f))
        assert [d.rule for d in diags] == ["AFF001"]
        assert diags[0].obj == "unguarded"

    def test_transitive_mutation_through_private_helper(self, tmp_path):
        f = tmp_path / "transitive.py"
        f.write_text(
            "class InferenceSession:\n"
            "    def _affine(self, method):\n"
            "        pass\n"
            "    def _helper(self):\n"
            "        self._tables.fill(0)\n"
            "    def public(self):\n"
            "        self._helper()\n")
        diags = lint_affinity(path=str(f))
        assert [d.rule for d in diags] == ["AFF001"]
        assert diags[0].obj == "public"


# ---------------------------------------------------------------------------
# lockdep runtime checker
# ---------------------------------------------------------------------------


class TestLockdepRuntime:
    def test_disabled_returns_plain_primitives(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        assert not S.enabled()
        m = S.make_lock("x")
        assert not isinstance(m, S._TrackedLock)

    def test_lock003_observed_order_inversion(self, sanitize_on):
        a, b = S.make_lock("A"), S.make_lock("B")
        with a:
            with b:
                pass
        with pytest.raises(SanitizerError) as ei:
            with b:
                with a:
                    pass
        assert ei.value.diagnostics[0].rule == "LOCK003"
        assert any(d.rule == "LOCK003" for d in S.runtime_findings())

    def test_lock003_declared_lattice_inversion(self, sanitize_on):
        eng = S.make_lock("engine.lock", reentrant=True)
        cv = S.make_condition("serving.cv")
        with pytest.raises(SanitizerError) as ei:
            with eng:
                with cv:
                    pass
        assert ei.value.diagnostics[0].rule == "LOCK003"

    def test_legal_lattice_nesting_is_quiet(self, sanitize_on):
        eng = S.make_lock("engine.lock", reentrant=True)
        cv = S.make_condition("serving.cv")
        hl = S.make_lock("frontend.hlock")
        with cv:
            with eng:
                pass
        with eng:
            with eng:  # reentrant self-nesting
                pass
        with hl:
            pass
        assert S.runtime_findings() == ()

    def test_lock004_wait_while_holding_another_lock(self, sanitize_on):
        eng = S.make_lock("engine.lock", reentrant=True)
        cv = S.make_condition("serving.cv")
        with pytest.raises(SanitizerError) as ei:
            with cv:
                with eng:
                    cv.wait(timeout=0.01)
        assert ei.value.diagnostics[0].rule == "LOCK004"

    def test_lock005_reacquire_non_reentrant(self, sanitize_on):
        m = S.make_lock("m")
        with pytest.raises(SanitizerError) as ei:
            with m:
                with m:
                    pass
        assert ei.value.diagnostics[0].rule == "LOCK005"

    def test_lock006_unlocked_structure_mutation(self, sanitize_on):
        g = S.make_lock("g")
        with pytest.raises(SanitizerError) as ei:
            S.require_held(g, "scheduler.FIFO")
        assert ei.value.diagnostics[0].rule == "LOCK006"
        with g:
            S.require_held(g, "scheduler.FIFO")  # held: quiet

    def test_require_held_is_noop_on_plain_locks(self):
        S.require_held(threading.Lock(), "anywhere")

    def test_scheduler_guard_fires_without_engine_lock(self, sanitize_on):
        from repro.deploy.serving.scheduler import FIFO

        sched = FIFO()
        sched.guard_lock = S.make_lock("engine.lock", reentrant=True)

        class H:
            rid, priority, arrival_t = 0, 0, 0.0
            ttft_slo_ms = deadline_ms = deadline_t = admit_deadline_t = None

        with pytest.raises(SanitizerError) as ei:
            sched.add(H(), 0.0)
        assert ei.value.diagnostics[0].rule == "LOCK006"
        with sched.guard_lock:
            sched.add(H(), 0.0)  # under the lock: quiet

    def test_reset_runtime_clears_order_and_findings(self, sanitize_on):
        a, b = S.make_lock("A2"), S.make_lock("B2")
        with a:
            with b:
                pass
        S.reset_runtime()
        # the A2->B2 edge is gone: acquiring in reverse is legal again
        with b:
            with a:
                pass
        # ... but records B2->A2, so the original order now inverts
        S.reset_runtime()
        assert S.runtime_findings() == ()


# ---------------------------------------------------------------------------
# shadow block-lifecycle sanitizer (BLK001..BLK005)
# ---------------------------------------------------------------------------


class TestShadowPool:
    def test_clean_lifecycle_is_quiet(self, sanitize_on):
        a = BlockAllocator(4)
        assert a.shadow is not None
        blks = a.allocate(2, owner=0)
        a.fork([blks[0]])
        fresh, copied = a.cow(blks[0], owner=1)
        assert copied and fresh != blks[0]
        a.shadow.write(1, fresh, a)  # COW_PENDING -> EXCLUSIVE
        a.free([fresh])
        a.free(blks)
        assert a.shadow.findings == []
        assert a.shadow.audit(a) == []
        snap = a.shadow.snapshot()
        assert snap["free"] == 4 and snap["findings"] == 0

    def test_disabled_means_no_shadow(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        assert BlockAllocator(4).shadow is None

    def test_blk001_use_after_free_write(self, sanitize_on):
        a = BlockAllocator(4)
        (b,) = a.allocate(1)
        a.free([b])
        with pytest.raises(SanitizerError) as ei:
            a.shadow.write(0, b, a)
        assert ei.value.diagnostics[0].rule == "BLK001"

    def test_blk001_fork_of_free_block(self, sanitize_on):
        # plain API misuse keeps the allocator's documented ValueError
        # (the sanitizer never changes exception types for errors the
        # allocator already catches) ...
        a = BlockAllocator(4)
        with pytest.raises(ValueError, match="not allocated"):
            a.fork([2])
        # ... the shadow hook exists for divergence the allocator
        # misses — a stale chain referencing a block it believes live:
        with pytest.raises(SanitizerError) as ei:
            a.shadow.fork([2], a)
        assert ei.value.diagnostics[0].rule == "BLK001"

    def test_blk002_double_free(self, sanitize_on):
        a = BlockAllocator(4)
        (b,) = a.allocate(1)
        a.free([b])
        with pytest.raises(ValueError, match="double free"):
            a.free([b])  # caller misuse: allocator error, unchanged
        with pytest.raises(SanitizerError) as ei:
            a.shadow.free([b], a)  # divergence path: BLK002
        assert ei.value.diagnostics[0].rule == "BLK002"

    def test_blk003_write_into_shared_block(self, sanitize_on):
        a = BlockAllocator(4)
        blks = a.allocate(2, owner=0)
        a.fork([blks[0]])
        with pytest.raises(SanitizerError) as ei:
            a.shadow.write(0, blks[0], a)
        assert ei.value.diagnostics[0].rule == "BLK003"
        a.shadow.findings.clear()
        a.shadow.write(0, blks[1], a)  # exclusive block: quiet
        assert a.shadow.findings == []

    def test_blk004_refcount_drift(self, sanitize_on):
        a = BlockAllocator(4)
        (b,) = a.allocate(1)
        a._ref[b] = 3  # out-of-band tamper, bypassing fork()
        with pytest.raises(SanitizerError) as ei:
            a.free([b])
        assert ei.value.diagnostics[0].rule == "BLK004"

    def test_blk005_conservation_leak_via_audit(self, sanitize_on):
        a = BlockAllocator(4)
        a.allocate(2)
        assert a.shadow.audit(a) == []
        del a._ref[1]  # leaked: neither free-listed nor refcounted
        diags = a.shadow.audit(a)
        assert any(d.rule == "BLK005" for d in diags)
        assert any(d.rule == "BLK004" for d in diags)
        assert all(d.source == "shadow" for d in diags)
        assert a.shadow.findings  # audit findings are recorded

    def test_failed_allocate_leaves_shadow_consistent(self, sanitize_on):
        from repro.deploy.paging import PoolExhausted

        a = BlockAllocator(2)
        a.allocate(2)
        with pytest.raises(PoolExhausted):
            a.allocate(1)
        assert a.shadow.audit(a) == []

    def test_scratch_block_writes_are_ignored(self, sanitize_on):
        a = BlockAllocator(2)
        a.shadow.write(0, 0, a)  # parked lanes scatter into scratch
        assert a.shadow.findings == []


# ---------------------------------------------------------------------------
# session integration: the _note_writes hook
# ---------------------------------------------------------------------------


class TestSessionShadowIntegration:
    def test_skipped_cow_caught_at_decode(self, paged_model, sanitize_on,
                                           monkeypatch):
        sess = paged_model.session(2)
        prompt = np.arange(10, dtype=np.int32)[None] % 50
        sess.prefill_slot(0, prompt)  # pos=10: mid-block (size 4)
        tail = sess.block_chain(0)[-1]
        sess.allocator.fork([tail])  # now shared with a phantom sibling
        monkeypatch.setattr(sess, "_cow_range",
                            lambda *a, **k: None)  # seeded: COW skipped
        with pytest.raises(SanitizerError) as ei:
            sess.decode(np.zeros((2,), np.int32), active=[True, False])
        assert ei.value.diagnostics[0].rule == "BLK003"

    def test_cow_path_keeps_decode_quiet(self, paged_model, sanitize_on):
        sess = paged_model.session(2)
        prompt = np.arange(10, dtype=np.int32)[None] % 50
        sess.prefill_slot(0, prompt)
        tail = sess.block_chain(0)[-1]
        sess.allocator.fork([tail])
        sess.decode(np.zeros((2,), np.int32), active=[True, False])
        assert sess.allocator.shadow.findings == []
        assert tail not in sess.block_chain(0)  # COW replaced it
        sess.allocator.free([tail])  # drop the phantom sibling's ref
        assert sess.allocator.shadow.audit(sess.allocator) == []

    def test_engine_run_is_quiet_under_sanitizer(self, paged_model, olmo,
                                                 sanitize_on):
        eng = Engine(paged_model, 2)
        for p in _prompts(olmo[0], 4):
            eng.submit(p, 3)
        while not eng.idle:
            eng.step()
        assert S.runtime_findings() == ()
        alloc = eng.session.allocator
        assert alloc.shadow.findings == []
        assert alloc.shadow.audit(alloc) == []
        assert eng.audit_sharing() == []


# ---------------------------------------------------------------------------
# bounded interleaving model checks
# ---------------------------------------------------------------------------


class TestInterleavings:
    def test_clean_protocols_verify(self):
        assert check_interleavings() == []

    @pytest.mark.parametrize("bug", ["skip_cow", "double_free", "drop_ref"])
    def test_seeded_block_protocol_bugs_caught(self, bug):
        diags = check_block_interleavings(bug=bug)
        assert diags and all(d.rule == "SCHED001" for d in diags)
        assert all("schedule" in d.hint for d in diags)  # trace attached

    @pytest.mark.parametrize("bug", ["cancel_direct", "admit_keeps_queued"])
    def test_seeded_scheduler_protocol_bugs_caught(self, bug):
        diags = check_scheduler_interleavings(bug=bug)
        assert diags and all(d.rule == "SCHED001" for d in diags)


# ---------------------------------------------------------------------------
# stats snapshot + /v1/stats sanitize section (satellites)
# ---------------------------------------------------------------------------


class TestStatsSnapshot:
    def test_snapshot_is_independent_copy(self, paged_model, olmo):
        eng = Engine(paged_model, 2)
        eng.submit(_prompts(olmo[0], 1)[0], 2)
        while not eng.idle:
            eng.step()
        snap = eng.stats_snapshot()
        n = len(snap.step_times_s)
        eng.submit(_prompts(olmo[0], 1, seed=1)[0], 2)
        while not eng.idle:
            eng.step()
        assert len(snap.step_times_s) == n  # later steps don't leak in
        assert len(eng.stats.step_times_s) > n

    def test_stats_payload_has_sanitize_section(self, paged_model, olmo,
                                                sanitize_on):
        from repro.deploy.serving.async_engine import AsyncEngine
        from repro.deploy.serving.frontend import _stats_payload

        with AsyncEngine(paged_model, 2) as eng:
            eng.submit(_prompts(olmo[0], 1)[0], 2).result(timeout=300)
            payload = _stats_payload(eng)
        sz = payload["sanitize"]
        assert sz["enabled"] is True
        assert sz["lockdep_findings"] == 0
        assert sz["shadow_findings"] == 0
        assert sz["audit_findings"] == 0

    def test_audit_source_tag(self, paged_model, olmo, sanitize_on):
        from repro.deploy.verify import verify_sharing

        sess = paged_model.session(2)
        sess.prefill_slot(0, np.arange(SEQ, dtype=np.int32)[None] % 50)
        assert verify_sharing(sess.sharing_state()) == []
        state = sess.sharing_state(index_blocks=(99,))  # out-of-range pin
        diags = verify_sharing(state, source="sanitizer")
        assert diags and all(d.source == "sanitizer" for d in diags)
        assert "[source=sanitizer]" in diags[0].format()
        assert all(d.source == "audit"
                   for d in verify_sharing(state))  # the default tag


# ---------------------------------------------------------------------------
# CLI: python -m repro.deploy.sanitize
# ---------------------------------------------------------------------------


class TestCLI:
    def test_repo_default_run_is_clean(self, capsys):
        assert S.main(["--strict", "--interleavings"]) == 0
        assert "OK — 0 error(s)" in capsys.readouterr().out

    def test_rc1_on_seeded_defect(self, tmp_path, capsys):
        f = tmp_path / "cycle.py"
        f.write_text(
            "import threading\n"
            "class C:\n"
            "    def __init__(self):\n"
            "        self.a = threading.Lock()\n"
            "        self.b = threading.Lock()\n"
            "    def ab(self):\n"
            "        with self.a:\n"
            "            with self.b:\n"
            "                pass\n"
            "    def ba(self):\n"
            "        with self.b:\n"
            "            with self.a:\n"
            "                pass\n")
        assert S.main([str(f)]) == 1
        assert "LOCK001" in capsys.readouterr().out

    def test_rc2_on_unparseable_file(self, tmp_path, capsys):
        f = tmp_path / "broken.py"
        f.write_text("def broken(:\n")
        assert S.main([str(f)]) == 2


# ---------------------------------------------------------------------------
# AsyncEngine thread stress under the sanitizer
# ---------------------------------------------------------------------------


class TestAsyncStress:
    def test_submit_cancel_drain_stress(self, paged_model, olmo, sanitize_on):
        from repro.deploy.serving.async_engine import AsyncEngine

        prompts = _prompts(olmo[0], 6)
        with AsyncEngine(paged_model, 2) as eng:
            handles, errs = [], []

            def client(lo, hi, cancel_every):
                try:
                    for i in range(lo, hi):
                        h = eng.submit(prompts[i], 4)
                        handles.append(h)
                        if i % cancel_every == 0:
                            h.cancel()
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    errs.append(e)

            ts = [threading.Thread(target=client, args=(0, 3, 2)),
                  threading.Thread(target=client, args=(3, 6, 3))]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert not errs, errs
            eng.drain(timeout=600)
            for h in handles:
                assert h.done
        assert S.runtime_findings() == ()
        alloc = eng.engine.session.allocator
        assert alloc.shadow.findings == []
        assert alloc.shadow.audit(alloc) == []

    def test_hypothesis_interleaving_stress(self, paged_model, olmo):
        hyp = pytest.importorskip(
            "hypothesis", reason="property stress needs the [test] extra")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        from repro.deploy.serving.async_engine import AsyncEngine

        prompts = _prompts(olmo[0], 4)

        @settings(max_examples=3, deadline=None)
        @given(cancels=st.lists(st.booleans(), min_size=4, max_size=4),
               gens=st.lists(st.integers(1, 4), min_size=4, max_size=4))
        def run(cancels, gens):
            os.environ["REPRO_SANITIZE"] = "1"
            try:
                S.reset_runtime()
                with AsyncEngine(paged_model, 2) as eng:
                    hs = [eng.submit(p, g)
                          for p, g in zip(prompts, gens)]
                    for h, c in zip(hs, cancels):
                        if c:
                            h.cancel()
                    eng.drain(timeout=600)
                assert S.runtime_findings() == ()
                alloc = eng.engine.session.allocator
                assert alloc.shadow.findings == []
            finally:
                os.environ.pop("REPRO_SANITIZE", None)

        run()

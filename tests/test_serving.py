"""Async serving frontend + SLO-aware scheduling (ISSUE 8).

Acceptance contract: :class:`AsyncEngine` token streams are bit-exact vs
the synchronous :class:`Engine` on identical request sets — ``w8a8`` and
``ita``, dense and paged KV — *including* preemption + requeue (a
requeued request's final stream is identical to an uninterrupted run);
``PriorityDeadline`` ordering is deterministic under a fake clock,
starvation-free under aging, and preempts exactly the over-budget
outranked residents; bounded queues shed with a structured
:class:`QueueFullError` or by displacing the worst-ranked queued request
when the newcomer outranks it; N producer threads submitting into one engine
all complete-or-shed with no duplicated or lost tokens; and the stdlib
HTTP frontend streams, reports status/stats, maps errors to structured
4xx/5xx and drains gracefully.
"""

import json
import threading
import urllib.error

import jax
import pytest

from repro.configs import get_config, reduced
from repro.deploy import api
from repro.deploy.engine import Engine, Temperature
from repro.deploy.serving.async_engine import AsyncEngine
from repro.deploy.serving.frontend import ServingFrontend
from repro.deploy.serving.scheduler import (
    FIFO,
    PriorityDeadline,
    QueueFullError,
    effective_deadline,
    make_scheduler,
)
from repro.launch.cli import http_generate, http_get_json
from repro.models import transformer as T

SEQ = 8
MAX_LEN = 24


@pytest.fixture(scope="module")
def olmo():
    cfg = reduced(get_config("olmo-1b"))
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    return cfg, params


def _compile(cfg, backend="w8a8", *, paged=False, max_len=MAX_LEN,
             kv_blocks=14):
    kw = dict(kv_block_size=4, kv_blocks=kv_blocks) if paged else {}
    return api.compile(cfg, backend=backend, seq_len=SEQ, max_len=max_len,
                       use_cache=False, **kw)


@pytest.fixture(scope="module")
def dense_model(olmo):
    return _compile(olmo[0])


def _prompts(cfg, n, *, lengths=(SEQ, SEQ + 2), seed=0):
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    return [
        [int(t) for t in jax.random.randint(jax.random.fold_in(key, i),
                                            (lengths[i % len(lengths)],), 0,
                                            cfg.vocab, jnp.int32)]
        for i in range(n)
    ]


class _FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def _mk(rid, *, priority=0, ttft=None, deadline=None, arrival=0.0):
    """Bare handle stand-in for scheduler unit tests (no engine)."""

    class H:
        pass

    h = H()
    h.rid = rid
    h.priority = priority
    h.ttft_slo_ms = ttft
    h.deadline_ms = deadline
    h.arrival_t = arrival
    h.deadline_t = None if deadline is None else arrival + deadline / 1e3
    h.admit_deadline_t = effective_deadline(arrival, ttft, deadline)
    return h


class TestSchedulerPolicies:
    def test_fifo_orders_by_submission_and_default_unbounded(self):
        s = FIFO()
        hs = [_mk(i) for i in range(50)]
        for h in hs:
            s.add(h, 0.0)
        assert [s.pop(0.0).rid for _ in range(50)] == list(range(50))
        assert s.pop(0.0) is None and s.peek(0.0) is None

    def test_bounded_queue_sheds_with_structured_error(self):
        s = FIFO(max_queue=2)
        s.add(_mk(0), 0.0)
        s.add(_mk(1), 0.1)
        with pytest.raises(QueueFullError) as ei:
            s.add(_mk(2), 0.2)
        e = ei.value
        assert e.queue_depth == 2 and e.max_queue == 2
        assert e.retry_after_s > 0
        # requeues are NOT shed: admission already happened once
        s.requeue(_mk(3), 0.3)
        assert len(s) == 3

    def test_displacement_sheds_worst_queued_for_outranking_arrival(self):
        s = PriorityDeadline(max_queue=2, aging_s=1e9)
        bg = [_mk(0, priority=5, ttft=900.0), _mk(1, priority=5, ttft=100.0)]
        assert s.add(bg[0], 0.0) is None and s.add(bg[1], 0.0) is None
        # an urgent newcomer displaces the WORST-ranked queued request
        # (bg[0]: later deadline), not whoever arrived last
        urgent = _mk(2, priority=0, ttft=50.0)
        assert s.add(urgent, 0.0) is bg[0]
        assert len(s) == 2
        assert [s.pop(0.0).rid for _ in range(2)] == [2, 1]
        # a newcomer that outranks nobody still sheds via QueueFullError
        s2 = PriorityDeadline(max_queue=1, aging_s=1e9)
        s2.add(_mk(0, priority=0, ttft=50.0), 0.0)
        with pytest.raises(QueueFullError):
            s2.add(_mk(1, priority=5), 0.0)
        # EXPIRED queued work is displaced first, for ANY newcomer —
        # past its admission deadline the shed can never cost goodput
        s3 = PriorityDeadline(max_queue=2, aging_s=1e9)
        doomed = _mk(0, priority=0, ttft=50.0)   # urgent, dead by now=1.0
        fresh = _mk(1, priority=5, ttft=5000.0)
        s3.add(doomed, 0.0)
        s3.add(fresh, 0.0)
        late_bg = _mk(2, priority=9)             # outranks nobody
        assert s3.add(late_bg, 1.0) is doomed
        assert sorted(h.rid for h in (s3.pop(1.0), s3.pop(1.0))) == [1, 2]
        # FIFO never displaces — equal-depth overflow is always a refusal
        f = FIFO(max_queue=1)
        assert f.add(_mk(0), 0.0) is None
        with pytest.raises(QueueFullError):
            f.add(_mk(1, priority=-10, ttft=1.0), 0.0)

    def test_engine_finishes_displaced_handle_as_shed(self, olmo,
                                                      dense_model):
        cfg, params = olmo
        eng = Engine(dense_model, 1, params=params,
                     scheduler=PriorityDeadline(max_queue=1))
        p = _prompts(cfg, 1)[0]
        bg = eng.submit(p, 2, priority=5)
        urgent = eng.submit(p, 2, priority=0, ttft_slo_ms=50.0)
        assert bg.done and bg.finish_reason == "shed"
        assert eng.stats.shed_requests == 1
        assert eng.stats.requests_evicted == 1
        eng.run_until_idle(max_steps=100)
        assert urgent.finish_reason == "length" and len(urgent.tokens) == 2

    def test_priority_dominates_then_deadline_then_arrival(self):
        s = PriorityDeadline(aging_s=1e9)  # aging off for this test
        urgent = _mk(2, priority=0, ttft=500.0)
        sooner = _mk(1, priority=5, ttft=100.0)
        later = _mk(0, priority=5, ttft=900.0)
        for h in (later, sooner, urgent):
            s.add(h, 0.0)
        assert [s.pop(0.0).rid for _ in range(3)] == [2, 1, 0]

    def test_arrival_breaks_exact_ties(self):
        s = PriorityDeadline(aging_s=1e9)
        a, b = _mk(0, priority=1), _mk(1, priority=1)
        s.add(b, 0.0)
        s.add(a, 0.0)
        assert s.pop(0.0).rid == 0  # same aged priority, same (inf)
        assert s.pop(0.0).rid == 1  # deadline -> submission order wins

    def test_aging_promotes_waiting_requests(self):
        s = PriorityDeadline(aging_s=1.0)
        old_low = _mk(0, priority=5, arrival=0.0)
        fresh_high = _mk(1, priority=0, arrival=9.0)
        s.add(old_low, 0.0)
        s.add(fresh_high, 9.0)
        # at t=9 old_low has aged 9 levels: 5-9=-4 < 0 -> admitted first
        assert s.pop(9.0).rid == 0

    def test_victims_only_over_budget_and_outranked(self):
        s = PriorityDeadline(aging_s=1e9)
        resident_ok = _mk(0, priority=5)                     # no deadline
        resident_over = _mk(1, priority=5, deadline=100.0)   # blown at t=1
        assert s.victims([resident_ok, resident_over], 1.0) == []  # queue empty
        s.add(_mk(2, priority=0), 1.0)  # strictly outranks rid=1
        v = s.victims([resident_ok, resident_over], 1.0)
        assert [h.rid for h in v] == [1]  # never the no-deadline resident
        # a queued request that does NOT outrank preempts nothing
        s2 = PriorityDeadline(aging_s=1e9)
        s2.add(_mk(3, priority=9), 1.0)
        assert s2.victims([resident_over], 1.0) == []

    def test_victims_capped_by_outranking_queue_depth(self):
        s = PriorityDeadline(aging_s=1e9)
        residents = [_mk(i, priority=5, deadline=100.0) for i in range(3)]
        s.add(_mk(10, priority=0), 1.0)  # ONE outranker
        assert len(s.victims(residents, 1.0)) == 1

    def test_registry_and_validation(self):
        assert isinstance(make_scheduler("fifo"), FIFO)
        pd = make_scheduler("priority-deadline", max_queue=4, aging_s=2.0)
        assert isinstance(pd, PriorityDeadline)
        assert pd.max_queue == 4 and pd.aging_s == 2.0
        with pytest.raises(ValueError, match="unknown scheduler"):
            make_scheduler("lifo")
        with pytest.raises(ValueError, match="aging_s"):
            PriorityDeadline(aging_s=0.0)
        with pytest.raises(ValueError, match="max_queue"):
            FIFO(max_queue=-1)

    def test_effective_deadline(self):
        import math

        assert effective_deadline(1.0, None, None) == math.inf
        assert effective_deadline(1.0, 500.0, None) == pytest.approx(1.5)
        assert effective_deadline(1.0, 500.0, 200.0) == pytest.approx(1.2)

    def test_engine_rejects_used_scheduler(self, olmo, dense_model):
        cfg, params = olmo
        s = FIFO()
        s.add(_mk(0), 0.0)
        with pytest.raises(ValueError, match="fresh"):
            Engine(dense_model, 1, params=params, scheduler=s)


class TestAsyncBitExact:
    @pytest.mark.parametrize("backend,paged", [
        ("w8a8", False), ("w8a8", True), ("ita", False), ("ita", True),
    ], ids=["w8a8-dense", "w8a8-paged", "ita-dense", "ita-paged"])
    def test_async_streams_match_sync_engine(self, olmo, backend, paged):
        """The background loop thread changes *when* steps happen, never
        what they compute: same request set, identical per-request
        streams vs the synchronous engine on every backend/KV combo."""
        cfg, params = olmo
        model = _compile(cfg, backend, paged=paged)
        n = 4 if backend == "w8a8" else 3
        prompts = _prompts(cfg, n, seed=3)
        gens = [3, 4, 2, 3][:n]

        sync = Engine(model, 2, params=params)
        ref = [sync.submit(p, g) for p, g in zip(prompts, gens)]
        sync.run_until_idle(max_steps=300)

        with AsyncEngine(model, 2, params=params) as eng:
            hs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
            streams = [[t for t in h] for h in hs]  # blocking iteration
            for h, r, stream in zip(hs, ref, streams):
                raw = h.result(timeout=120)
                assert raw.tokens == r.tokens
                assert stream == r.tokens
                assert raw.finish_reason == r.finish_reason

    @pytest.mark.parametrize("paged", [False, True],
                             ids=["dense", "paged"])
    def test_preempted_requeued_stream_is_bit_exact(self, olmo, paged):
        """A resident evicted back to the queue resumes with its full
        prefix teacher-forced and the sampling index unchanged — the
        final stream equals an uninterrupted run (temperature sampling,
        so any index/slot drift would diverge instantly)."""
        cfg, params = olmo
        model = _compile(cfg, paged=paged)

        ref_eng = Engine(model, 1, params=params,
                         sampling=Temperature(0.8, jax.random.PRNGKey(3)))
        ref = ref_eng.submit(list(range(10)), 8)
        ref_eng.run_until_idle(max_steps=200)

        clk = _FakeClock()
        eng = Engine(model, 1, params=params,
                     sampling=Temperature(0.8, jax.random.PRNGKey(3)),
                     scheduler=PriorityDeadline(), clock=clk)
        h = eng.submit(list(range(10)), 8, priority=5, deadline_ms=100)
        for _ in range(6):  # admit + generate a few tokens
            eng.step()
        assert h.tokens, "setup: nothing generated before preemption"
        clk.t = 1.0  # blow h's completion budget
        hi = eng.submit(list(range(8)), 2, priority=0)
        eng.run_until_idle(max_steps=300)
        assert h.preemptions >= 1
        assert h.tokens == ref.tokens
        assert h.finish_reason == ref.finish_reason
        assert hi.finish_reason == "length" and len(hi.tokens) == 2
        assert eng.stats.preemptions == eng.stats.requeues == h.preemptions

    def test_requeued_request_streams_each_token_once(self, olmo):
        """Preemption must not re-fire on_token for already-streamed
        tokens: the resumed prefix is teacher-forced, not re-sampled."""
        cfg, params = olmo
        model = _compile(cfg)
        seen = []
        clk = _FakeClock()
        eng = Engine(model, 1, params=params,
                     scheduler=PriorityDeadline(), clock=clk)
        h = eng.submit(list(range(10)), 6, priority=5, deadline_ms=100,
                       on_token=seen.append)
        for _ in range(5):
            eng.step()
        clk.t = 1.0
        eng.submit(list(range(8)), 1, priority=0)
        eng.run_until_idle(max_steps=200)
        assert h.preemptions >= 1
        assert seen == h.tokens  # every token exactly once, in order


class TestAsyncLifecycle:
    def test_idle_engine_does_not_busy_spin(self, olmo, dense_model):
        cfg, params = olmo
        with AsyncEngine(dense_model, 1, params=params) as eng:
            eng.submit(_prompts(cfg, 1)[0], 2).result(timeout=120)
            steps_after_drain = len(eng.stats.step_times_s)
            import time

            time.sleep(0.25)  # idle: the loop must be waiting, not stepping
            assert len(eng.stats.step_times_s) == steps_after_drain

    def test_result_timeout_raises(self, olmo, dense_model):
        cfg, params = olmo
        with AsyncEngine(dense_model, 1, params=params) as eng:
            h = eng.submit(_prompts(cfg, 1)[0], 14)
            with pytest.raises(TimeoutError, match="not finished"):
                h.result(timeout=1e-4)
            assert h.result(timeout=120).finish_reason == "length"

    def test_submit_after_close_raises(self, olmo, dense_model):
        cfg, params = olmo
        eng = AsyncEngine(dense_model, 1, params=params)
        eng.close()
        with pytest.raises(RuntimeError, match="closed"):
            eng.submit(_prompts(cfg, 1)[0], 2)

    def test_close_without_drain_cancels_live_work(self, olmo, dense_model):
        cfg, params = olmo
        eng = AsyncEngine(dense_model, 1, params=params)
        hs = [eng.submit(p, 30) for p in _prompts(cfg, 3)]
        eng.close(drain=False, timeout=60)
        assert all(h.done for h in hs)
        assert any(h.finish_reason == "cancelled" for h in hs)

    def test_cancel_from_other_thread(self, olmo, dense_model):
        cfg, params = olmo
        with AsyncEngine(dense_model, 1, params=params) as eng:
            hs = [eng.submit(p, 10) for p in _prompts(cfg, 3)]
            hs[2].cancel()   # still queued behind hs[1]
            hs[0].cancel()   # possibly resident: routed to the loop thread
            done = hs[1].result(timeout=120)
            assert done.finish_reason == "length"
            for h in (hs[0], hs[2]):
                assert h.result(timeout=120).finish_reason == "cancelled"

    def test_threaded_producers_all_complete_or_shed(self, olmo, dense_model):
        """N producer threads hammer one bounded-queue engine: every
        submission either completes with its exact single-request
        reference stream (no lost/duplicated/cross-wired tokens) or is
        shed with QueueFullError — and the stats account for all of it."""
        cfg, params = olmo
        prompts = _prompts(cfg, 4, seed=5)

        ref_eng = Engine(dense_model, 2, params=params)
        refs = [ref_eng.submit(p, 4) for p in prompts]
        ref_eng.run_until_idle(max_steps=300)
        expect = {i: r.tokens for i, r in enumerate(refs)}

        results: dict[tuple[int, int], list] = {}
        shed = []
        with AsyncEngine(dense_model, 2, params=params,
                         scheduler=FIFO(max_queue=6)) as eng:
            def producer(t):
                for j in range(4):
                    try:
                        h = eng.submit(prompts[j], 4)
                    except QueueFullError:
                        shed.append((t, j))
                        continue
                    raw = h.result(timeout=120)
                    results[(t, j)] = (raw.tokens, raw.finish_reason)

            threads = [threading.Thread(target=producer, args=(t,))
                       for t in range(4)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            eng.drain(timeout=120)
            stats = eng.stats

        assert len(results) + len(shed) == 16
        for (_, j), (tokens, reason) in results.items():
            assert reason == "length"
            assert tokens == expect[j]  # greedy folds rid-free: exact match
        assert stats.requests_completed == len(results)
        assert stats.shed_requests == len(shed)
        assert stats.requests_submitted == len(results)

    def test_adopting_busy_engine_rejected(self, olmo, dense_model):
        cfg, params = olmo
        sync = Engine(dense_model, 1, params=params)
        sync.submit(_prompts(cfg, 1)[0], 2)
        with pytest.raises(ValueError, match="live work"):
            AsyncEngine(sync)


class TestSubmitValidation:
    # empty-prompt / short / over-max_len / pool-impossible refusals are
    # regression-tested in tests/test_engine.py; here only the SLO
    # contract fields added by this layer
    def test_negative_slo_rejected(self, olmo, dense_model):
        cfg, params = olmo
        eng = Engine(dense_model, 1, params=params)
        with pytest.raises(ValueError, match="ttft_slo_ms"):
            eng.submit([1] * SEQ, 2, ttft_slo_ms=-1.0)
        with pytest.raises(ValueError, match="deadline_ms"):
            eng.submit([1] * SEQ, 2, deadline_ms=-5.0)


class TestLatencyStats:
    def test_ttft_tpot_recorded_per_generated_token(self, olmo, dense_model):
        cfg, params = olmo
        eng = Engine(dense_model, 2, params=params)
        hs = [eng.submit(p, 3) for p in _prompts(cfg, 2)]
        eng.run_until_idle(max_steps=200)
        s = eng.stats
        assert len(s.ttft_s) == 2                      # one per request
        assert len(s.tpot_s) == sum(len(h.tokens) for h in hs) - 2
        assert all(t >= 0 for t in s.ttft_s + s.tpot_s)
        assert s.ttft(50) <= s.ttft(99)
        for h in hs:
            assert h.ttft_s is not None and h.finish_t is not None

    def test_goodput_under_slo_with_fake_clock(self, olmo, dense_model):
        cfg, params = olmo
        clk = _FakeClock()
        eng = Engine(dense_model, 1, params=params, clock=clk)
        met = eng.submit([1] * SEQ, 2, ttft_slo_ms=1e6)
        missed = eng.submit([2] * SEQ, 2, ttft_slo_ms=1.0)
        while not eng.idle:
            clk.t += 0.050  # 50 ms per scheduler step
            eng.step()
        assert met.ttft_s is not None and met.ttft_s <= 1e3
        assert missed.ttft_s > 1e-3
        assert eng.stats.goodput_under_slo() == pytest.approx(0.5)

    def test_summary_mentions_slo_and_preemption_counters(self, olmo,
                                                          dense_model):
        cfg, params = olmo
        eng = Engine(dense_model, 1, params=params)
        eng.submit([1] * SEQ, 2)
        eng.run_until_idle(max_steps=100)
        s = eng.stats.summary()
        assert "ttft p50/p99" in s and "tpot p50/p99" in s
        eng.stats.preemptions = 2
        eng.stats.requeues = 2
        eng.stats.shed_requests = 1
        assert "2 preemptions / 2 requeues / 1 shed" in eng.stats.summary()


class TestSessionThreadAffinity:
    def test_mutation_from_second_thread_rejected(self, olmo, dense_model):
        cfg, params = olmo
        eng = Engine(dense_model, 1, params=params)
        eng.submit(_prompts(cfg, 1)[0], 2)
        eng.run_until_idle(max_steps=100)  # binds the session to this thread
        errors = []

        def intruder():
            try:
                eng.session.free_slot(0)
            except RuntimeError as e:
                errors.append(str(e))

        t = threading.Thread(target=intruder)
        t.start()
        t.join()
        assert len(errors) == 1 and "rebind_thread" in errors[0]

    def test_rebind_transfers_ownership(self, olmo, dense_model):
        cfg, params = olmo
        session = dense_model.session(1, params=params)
        session.free_slot(0)  # binds here
        ok = []

        def new_owner():
            session.rebind_thread()
            session.free_slot(0)
            ok.append(True)

        t = threading.Thread(target=new_owner)
        t.start()
        t.join()
        assert ok == [True]


class TestFrontend:
    @pytest.fixture()
    def served(self, olmo, dense_model):
        cfg, params = olmo
        eng = AsyncEngine(dense_model, 2, params=params,
                          scheduler=PriorityDeadline(max_queue=32))
        fe = ServingFrontend(eng, port=0)
        host, port = fe.start()
        yield cfg, host, port, fe
        if fe._thread.is_alive():
            fe.shutdown(drain=False, timeout=60)

    def test_streaming_matches_final_summary(self, olmo, served):
        cfg, host, port, _ = served
        prompt = _prompts(cfg, 1)[0]
        events = list(http_generate(host, port, prompt, 4))
        toks = [e["token"] for e in events if "token" in e]
        final = events[-1]
        assert final["done"] and final["finish_reason"] == "length"
        assert final["tokens"] == toks and len(toks) == 4
        assert [e["index"] for e in events if "token" in e] == [0, 1, 2, 3]

    def test_unary_status_stats_roundtrip(self, olmo, served):
        cfg, host, port, _ = served
        out = http_generate(host, port, _prompts(cfg, 1)[0], 3, stream=False,
                            priority=1, ttft_slo_ms=60_000.0)
        assert out["finish_reason"] == "length" and len(out["tokens"]) == 3
        st = http_get_json(host, port, f"/v1/status/{out['rid']}")
        assert st["status"] == "done" and st["tokens_generated"] == 3
        stats = http_get_json(host, port, "/v1/stats")
        assert stats["requests_completed"] >= 1
        assert stats["goodput_under_slo"] == pytest.approx(1.0)
        assert http_get_json(host, port, "/healthz")["status"] == "ok"

    def test_bad_request_is_structured_400(self, olmo, served):
        cfg, host, port, _ = served
        with pytest.raises(urllib.error.HTTPError) as ei:
            http_generate(host, port, [], 3, stream=False)
        assert ei.value.code == 400
        body = json.loads(ei.value.read().decode())
        assert body["type"] == "ValueError" and "empty prompt" in body["error"]

    def test_unknown_rid_is_404(self, served):
        _, host, port, _ = served
        with pytest.raises(urllib.error.HTTPError) as ei:
            http_get_json(host, port, "/v1/status/999999")
        assert ei.value.code == 404

    def test_shed_is_429_with_retry_after(self, olmo, dense_model):
        cfg, params = olmo
        eng = AsyncEngine(dense_model, 1, params=params,
                          scheduler=FIFO(max_queue=0))  # sheds everything
        fe = ServingFrontend(eng, port=0)
        host, port = fe.start()
        try:
            with pytest.raises(urllib.error.HTTPError) as ei:
                http_generate(host, port, _prompts(cfg, 1)[0], 2, stream=False)
            assert ei.value.code == 429
            assert int(ei.value.headers["Retry-After"]) >= 1
            body = json.loads(ei.value.read().decode())
            assert body["type"] == "QueueFullError"
            assert body["retry_after_s"] > 0 and body["max_queue"] == 0
            assert eng.stats.shed_requests == 1
        finally:
            fe.shutdown(drain=False, timeout=60)

    def test_graceful_drain_finishes_streams_then_refuses(self, olmo,
                                                          dense_model):
        cfg, params = olmo
        eng = AsyncEngine(dense_model, 2, params=params)
        fe = ServingFrontend(eng, port=0)
        host, port = fe.start()
        h = eng.submit(_prompts(cfg, 1)[0], 6)
        fe.draining = True  # the first phase of shutdown()
        with pytest.raises(urllib.error.HTTPError) as ei:
            http_generate(host, port, _prompts(cfg, 1)[0], 2, stream=False)
        assert ei.value.code == 503
        assert http_get_json(host, port, "/healthz")["status"] == "draining"
        fe.shutdown(drain=True, timeout=120)   # in-flight request finishes
        assert h.done and h.finish_reason == "length"
        with pytest.raises(urllib.error.URLError):
            http_get_json(host, port, "/healthz")  # listener gone

"""Substrate tests: checkpoint/restart, fault supervision, elastic remesh,
gradient compression, sharding rules, data pipeline, HLO analysis."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.optim import adamw, compression
from repro.runtime import elastic
from repro.runtime.fault import StragglerDetector, Supervisor


class TestCheckpointer:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {
            "a": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
        }

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = self._tree()
        ck.save(7, tree)
        assert ck.latest_step() == 7
        restored = ck.restore(7, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_then_restore(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = self._tree(1)
        ck.save_async(3, tree)
        ck.wait()
        step, restored = ck.restore_latest(tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    def test_crash_mid_save_preserves_previous(self, tmp_path):
        """A stale .tmp dir must not corrupt LATEST."""
        ck = Checkpointer(str(tmp_path))
        tree = self._tree(2)
        ck.save(1, tree)
        os.makedirs(os.path.join(str(tmp_path), "step_2.tmp999"), exist_ok=True)
        assert ck.latest_step() == 1
        _, restored = ck.restore_latest(tree)
        assert restored is not None

    def test_shape_mismatch_detected(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, self._tree())
        bad = {"a": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros(10, jnp.int32)}}
        with pytest.raises(ValueError):
            ck.restore(1, bad)


class TestSupervisor:
    def test_restart_after_injected_failure(self, tmp_path):
        """A mid-run failure restores the last checkpoint and replays."""
        ck = Checkpointer(str(tmp_path))

        def step_fn(state, batch):
            return {"x": state["x"] + batch}, {"loss": float(state["x"])}

        def batch_fn(step):
            return jnp.asarray(1.0)

        failed = {"done": False}

        def inject(step):
            if step == 7 and not failed["done"]:
                failed["done"] = True
                raise RuntimeError("simulated node failure")

        sup = Supervisor(ck, save_every=5)
        state, hist = sup.run(
            step_fn, {"x": jnp.asarray(0.0)}, batch_fn, 0, 10, inject_failure=inject
        )
        # deterministic replay: final state == 10 regardless of the failure
        assert float(state["x"]) == 10.0
        steps = [s for s, _ in hist]
        assert steps[-1] == 9 and 7 in steps

    def test_straggler_detection(self):
        det = StragglerDetector(window=16, threshold=2.0)
        for _ in range(10):
            assert not det.observe(0.1)
        assert det.observe(0.5)  # 5x median
        assert det.flags == 1


class TestElastic:
    def test_plan_mesh_preserves_model_axis(self):
        (data, model), names = elastic.plan_mesh(96, 16)
        assert model == 16 and data == 6
        with pytest.raises(ValueError):
            elastic.plan_mesh(8, 16)

    def test_remesh_and_reshard_on_host(self):
        devs = jax.devices()
        mesh = elastic.remesh(devs, 1)
        params = {"mlp": {"up": {"w": jnp.ones((8, 4))}}}
        out = elastic.reshard_state(params, mesh)
        np.testing.assert_array_equal(np.asarray(out["mlp"]["up"]["w"]), np.ones((8, 4)))


class TestGradientCompression:
    def test_compress_decompress_error_feedback(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
        err = jnp.zeros_like(g)
        q, scale, err2 = compression.compress(g, err)
        assert q.dtype == jnp.int8
        deq = compression.decompress(q, scale, g.shape, (-1000) % compression.BLOCK)
        # quantization error captured by the feedback buffer
        np.testing.assert_allclose(np.asarray(deq + err2), np.asarray(g), atol=1e-6)

    def test_error_feedback_unbiased_over_steps(self):
        """Sum of dequantized grads + final error == sum of true grads."""
        rng = np.random.default_rng(1)
        err = jnp.zeros((257,), jnp.float32)
        total_true = np.zeros(257)
        total_deq = np.zeros(257)
        for i in range(20):
            g = jnp.asarray(rng.normal(size=(257,)) * 0.1, jnp.float32)
            q, scale, err = compression.compress(g, err)
            deq = compression.decompress(q, scale, g.shape, (-257) % compression.BLOCK)
            total_true += np.asarray(g)
            total_deq += np.asarray(deq)
        np.testing.assert_allclose(total_deq + np.asarray(err), total_true, atol=1e-4)

    def test_compressed_psum_exactness_int32(self):
        """int8 payload summed in int32 across shards is exact for the
        shared-scale grid."""
        import jax

        def f(g, err):
            return compression.compressed_psum(g, err, "i")

        g = jnp.stack([jnp.full((compression.BLOCK,), 0.5), jnp.full((compression.BLOCK,), -0.25)])
        err = jnp.zeros_like(g)
        out, _ = jax.vmap(f, axis_name="i")(g, err)
        np.testing.assert_allclose(np.asarray(out[0]), 0.25, atol=0.01)


class TestAdamW:
    def test_descends_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw.apply(grads, state, params, lr=0.1, weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.2

    def test_grad_clipping(self):
        g, norm = adamw.clip_by_global_norm({"w": jnp.full((4,), 100.0)}, 1.0)
        assert float(norm) > 100
        assert abs(float(adamw.global_norm(g)) - 1.0) < 1e-5


class TestDataPipeline:
    def test_deterministic_replay(self):
        from repro.configs import ShapeCell, get_config, reduced
        from repro.data import DataConfig, make_batch

        cfg = reduced(get_config("olmo-1b"))
        cell = ShapeCell("t", 64, 4, "train")
        dcfg = DataConfig(vocab=cfg.vocab, global_batch=4, seq_len=64)
        a = make_batch(cfg, cell, dcfg, step=17)
        b = make_batch(cfg, cell, dcfg, step=17)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = make_batch(cfg, cell, dcfg, step=18)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_prefetch_iterator(self):
        from repro.configs import ShapeCell, get_config, reduced
        from repro.data import DataConfig, PrefetchIterator, make_batch

        cfg = reduced(get_config("olmo-1b"))
        cell = ShapeCell("t", 32, 2, "train")
        dcfg = DataConfig(vocab=cfg.vocab, global_batch=2, seq_len=32)
        it = PrefetchIterator(cfg, cell, dcfg)
        step, batch = next(it)
        want = make_batch(cfg, cell, dcfg, step)
        np.testing.assert_array_equal(batch["tokens"], want["tokens"])
        it.close()


class TestHloAnalysis:
    def test_exact_on_nested_scan(self):
        from repro.deploy.hlo_analysis import analyze_hlo

        def model(params, x):
            def outer(x, _):
                def body(x, w):
                    return jnp.tanh(x @ w), None

                x, _ = jax.lax.scan(body, x, params)
                return x, None

            x, _ = jax.lax.scan(outer, x, None, length=3)
            return x.sum()

        params = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
        c = jax.jit(model).lower(params, x).compile()
        r = analyze_hlo(c.as_text())
        want = 2 * 32 * 128 * 128 * 6 * 3
        assert abs(r["flops"] - want) / want < 1e-6


class TestShardingRules:
    def test_param_specs(self):
        from jax.sharding import PartitionSpec as P

        from repro.runtime.sharding import spec_for_param

        assert spec_for_param("layers/attn/wqkv/w", 3) == P(None, None, "model")
        assert spec_for_param("layers/mlp/down/w_q", 3) == P(None, "model", None)
        assert spec_for_param("layers/mlp/experts/gate_q", 4) == P(None, "model", None, None)
        assert spec_for_param("embed/table", 2) == P("model", None)
        assert spec_for_param("layers/norm1/g_q", 2) == P()

    def test_fsdp_adds_data_axis(self):
        from jax.sharding import PartitionSpec as P

        from repro.runtime.sharding import spec_for_param

        assert spec_for_param("layers/attn/wqkv/w", 3, fsdp=True) == P(None, "data", "model")
        assert spec_for_param("layers/attn/wo/w", 3, fsdp=True) == P(None, "model", "data")

    def test_sanitize_drops_nondivisible(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_host_mesh
        from repro.runtime.sharding import sanitize_spec

        mesh = make_host_mesh(1, 1)
        # 'data' axis size 1 always divides; fake larger via spec check on odd dim
        s = sanitize_spec(mesh, P("data", None), (7, 3))
        assert s == P("data", None) or s == P(None, None)

"""Static plan verifier: mutation suite + shipping-config cleanliness.

The contract under test (ISSUE 7): every statically decidable hazard
class is caught with the right rule id, and every artifact the flow
actually ships verifies clean — including under ``--strict``.

The mutation tests work on the JSON form (``to_dict`` -> surgical edit ->
``from_dict(validate=False)``): that is exactly the CLI's threat model
(artifacts corrupted on disk or by hand), and ``validate=False`` keeps
the constructor's asserts from dying before the verifier can report.
"""

import json

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.deploy import api
from repro.deploy.plan import DecoderPlanPair
from repro.deploy.verify import (
    PlanVerificationError,
    check,
    load_artifact,
    main,
    verify,
    verify_plan,
)


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("olmo-1b"))


@pytest.fixture(scope="module")
def dense_pair(cfg):
    """Unfused dense pair: flat node lists make surgical edits easy."""
    return api.compile(cfg, seq_len=8, max_len=14, fuse=False,
                       use_cache=False).artifact


@pytest.fixture(scope="module")
def fused_pair(cfg):
    return api.compile(cfg, seq_len=8, max_len=14, use_cache=False).artifact


@pytest.fixture(scope="module")
def paged_pair(cfg):
    return api.compile(cfg, seq_len=8, max_len=14, kv_block_size=4,
                       kv_blocks=8, fuse=False, use_cache=False).artifact


def _mutated(pair, mutate, which="decode"):
    d = pair.to_dict()
    mutate(d[which] if which else d)
    return DecoderPlanPair.from_dict(d, validate=False)


def _error_rules(artifact):
    return {d.rule for d in verify(artifact) if d.severity == "error"}


# ---------------------------------------------------------------------------
# shipping configs verify clean (strict: zero diagnostics)
# ---------------------------------------------------------------------------

class TestShippingClean:
    def test_dense_pairs_clean(self, dense_pair, fused_pair):
        assert verify(dense_pair) == []
        assert verify(fused_pair) == []

    def test_paged_pair_clean(self, paged_pair):
        assert verify(paged_pair) == []

    def test_autotuned_clean(self, cfg):
        m = api.compile(cfg, seq_len=8, max_len=14, autotune=True,
                        use_cache=False)
        assert verify(m.artifact) == []

    def test_encoder_clean(self):
        m = api.compile(reduced(get_config("mobilebert")), seq_len=64,
                        use_cache=False)
        assert verify(m.artifact) == []

    def test_check_strict_passes_shipping(self, fused_pair, paged_pair):
        assert check(fused_pair, strict=True) == []
        assert check(paged_pair, strict=True) == []


# ---------------------------------------------------------------------------
# mutation suite: one defect class -> its rule id
# ---------------------------------------------------------------------------

class TestMutations:
    def test_offset_overlap_mem001(self, dense_pair):
        def overlap(p):
            kv = {n for kv_pair in p["kv_state"] for n in kv_pair if n}
            for n in p["nodes"]:
                cands = [
                    t for t in n["inputs"]
                    if t in p["tensors"] and not p["tensors"][t]["weight"]
                    and p["tensors"][t]["offset"] is not None
                    and p["tensors"][t]["size"] > 0 and t not in kv
                ]
                if len(cands) >= 2 and (p["tensors"][cands[0]]["offset"]
                                        != p["tensors"][cands[1]]["offset"]):
                    p["tensors"][cands[0]]["offset"] = \
                        p["tensors"][cands[1]]["offset"]
                    return
            raise AssertionError("no co-live activation pair found")

        rules = _error_rules(_mutated(dense_pair, overlap))
        assert "MEM001" in rules

    def test_def_before_use_df001(self, dense_pair):
        def swap_dependent(p):
            nodes = p["nodes"]
            for i in range(len(nodes) - 1):
                if set(nodes[i]["outputs"]) & set(nodes[i + 1]["inputs"]):
                    nodes[i], nodes[i + 1] = nodes[i + 1], nodes[i]
                    sched = p["schedule"]
                    sched[i], sched[i + 1] = sched[i + 1], sched[i]
                    return
            raise AssertionError("no adjacent dependent nodes")

        rules = _error_rules(_mutated(dense_pair, swap_dependent))
        assert "DF001" in rules

    def test_kv_war_hazard_kv001(self, dense_pair):
        def stale_read(p):
            cin, cout = p["kv_state"][0]
            for n in p["nodes"]:
                if cout in n["inputs"]:
                    n["inputs"] = [cin if t == cout else t
                                   for t in n["inputs"]]
                    return
            raise AssertionError(f"no reader of {cout}")

        rules = _error_rules(_mutated(dense_pair, stale_read))
        assert "KV001" in rules

    def test_pair_offset_mismatch_kv002(self, dense_pair):
        def swap_cache_offsets(p):
            (k_in, k_out), (v_in, v_out) = p["kv_state"][0], p["kv_state"][1]
            t = p["tensors"]
            ko, vo = t[k_in]["offset"], t[v_in]["offset"]
            for name in (k_in, k_out):
                t[name]["offset"] = vo
            for name in (v_in, v_out):
                t[name]["offset"] = ko

        rules = _error_rules(_mutated(dense_pair, swap_cache_offsets))
        assert "KV002" in rules

    def test_barrier_crossing_fusion_kv003(self, fused_pair):
        def merge_barrier(p):
            nodes = p["nodes"]
            for i in range(len(nodes) - 1):
                region, cw = nodes[i], nodes[i + 1]
                if region["kind"] == "fused_region" and \
                        cw["kind"] in ("cache_write", "cache_write_paged"):
                    produced = {o for b in region["body"]
                                for o in b["outputs"]}
                    region["inputs"] = list(region["inputs"]) + [
                        t for t in cw["inputs"]
                        if t not in produced and t not in region["inputs"]
                    ]
                    region["body"] = list(region["body"]) + [cw]
                    region["outputs"] = (list(region["outputs"])
                                         + list(cw["outputs"]))
                    del nodes[i + 1]
                    p["schedule"] = [n["name"] for n in nodes]
                    return
            raise AssertionError("no region adjacent to a cache write")

        rules = _error_rules(_mutated(fused_pair, merge_barrier))
        assert "KV003" in rules

    def test_scale_overflow_qnt001(self, dense_pair):
        def blow_up_weight_scale(p):
            for n in p["nodes"]:
                if n["kind"] == "gemm":
                    s = n["attrs"]["scales"]
                    n["attrs"]["scales"] = [s[0], 1e6, s[2]]
                    return
            raise AssertionError("no gemm node")

        rules = _error_rules(_mutated(dense_pair, blow_up_weight_scale))
        assert "QNT001" in rules

    def test_illegal_engine_eng001(self, dense_pair):
        def flip_engine(p):
            for n in p["nodes"]:
                if n["kind"] == "cache_write":
                    n["engine"] = "ita"
                    return
            raise AssertionError("no cache_write node")

        rules = _error_rules(_mutated(dense_pair, flip_engine))
        assert "ENG001" in rules

    def test_paged_scratch_read_kv004(self, paged_pair):
        def direct_pool_access(p):
            for n in p["nodes"]:
                if n["kind"] == "attn_paged":
                    n["kind"] = "attn_cached"
                    return
            raise AssertionError("no attn_paged node")

        rules = _error_rules(_mutated(paged_pair, direct_pool_access))
        assert "KV004" in rules

    # -- beyond the required eight ----------------------------------------

    def test_accumulator_overflow_qnt002(self, dense_pair):
        def deepen_contraction(p):
            for n in p["nodes"]:
                if n["kind"] == "gemm":
                    m, _, nn = n["attrs"]["dims"]
                    n["attrs"]["dims"] = [m, 150_000, nn]
                    return

        rules = _error_rules(_mutated(dense_pair, deepen_contraction))
        assert "QNT002" in rules

    def test_paged_geometry_kv005(self, paged_pair):
        def corrupt_pool_shape(p):
            cin, _ = p["kv_state"][0]
            shape = p["tensors"][cin]["shape"]
            p["tensors"][cin]["shape"] = [shape[0] + 1] + list(shape[1:])

        rules = _error_rules(_mutated(paged_pair, corrupt_pool_shape))
        assert "KV005" in rules

    def test_beyond_peak_mem002(self, dense_pair):
        def move_past_peak(p):
            for name, t in p["tensors"].items():
                if not t["weight"] and t["offset"] is not None and t["size"]:
                    t["offset"] = p["memory_peak"] + 64
                    return

        rules = _error_rules(_mutated(dense_pair, move_past_peak))
        assert "MEM002" in rules

    def test_schedule_desync_df004(self, dense_pair):
        def rename_in_schedule(p):
            p["schedule"][0] = "bogus_node"

        rules = _error_rules(_mutated(dense_pair, rename_in_schedule))
        assert "DF004" in rules

    def test_unknown_kind_eng002(self, dense_pair):
        def alien_kind(p):
            p["nodes"][0]["kind"] = "quantum_annealer"

        rules = _error_rules(_mutated(dense_pair, alien_kind))
        assert "ENG002" in rules


# ---------------------------------------------------------------------------
# severities, check(), compile()/load() wiring
# ---------------------------------------------------------------------------

def _decomp_warning_pair(dense_pair):
    """k=16384 keeps the int32 accumulator legal but provably exceeds the
    exact requant decomposition bound for any maximized multiplier."""
    def widen(p):
        for n in p["nodes"]:
            if n["kind"] == "gemm":
                m, _, nn = n["attrs"]["dims"]
                n["attrs"]["dims"] = [m, 16_384, nn]
                return
    return _mutated(dense_pair, widen)


class TestSeveritiesAndWiring:
    def test_decomposition_bound_is_warning_not_error(self, dense_pair):
        mutant = _decomp_warning_pair(dense_pair)
        diags = verify(mutant)
        assert diags and all(d.severity == "warning" for d in diags)
        assert {d.rule for d in diags} == {"QNT002"}
        # non-strict check returns them; strict check raises
        assert check(mutant) == diags
        with pytest.raises(PlanVerificationError):
            check(mutant, strict=True)

    def test_error_raises_with_all_diagnostics(self, dense_pair):
        def two_defects(p):
            p["schedule"][0] = "bogus_node"
            for n in p["nodes"]:
                if n["kind"] == "gemm":
                    s = n["attrs"]["scales"]
                    n["attrs"]["scales"] = [s[0], 1e6, s[2]]
                    break

        mutant = _mutated(dense_pair, two_defects)
        with pytest.raises(PlanVerificationError) as ei:
            check(mutant, context="unit-test")
        rules = {d.rule for d in ei.value.diagnostics}
        assert {"DF004", "QNT001"} <= rules
        assert "unit-test" in str(ei.value)

    def test_compile_records_verification(self, cfg):
        m = api.compile(cfg, seq_len=8, max_len=14, use_cache=False)
        assert m.diagnostics == ()
        assert m.verify_ms > 0.0

    def test_compile_verify_false_skips(self, cfg):
        m = api.compile(cfg, seq_len=8, max_len=14, use_cache=False,
                        verify=False)
        assert m.diagnostics == () and m.verify_ms == 0.0

    def test_cache_hit_is_reverified(self, cfg, tmp_path):
        """A cached artifact edited on disk (in a way the constructor's
        asserts cannot see — an engine flip) must fail the re-verifying
        cache-hit path, not execute on the wrong engine."""
        cache = str(tmp_path / "plans")
        kw = dict(seq_len=8, max_len=14, fuse=False, cache_dir=cache)
        m = api.compile(cfg, **kw)
        assert not m.cache_hit and m.cache_path
        payload = json.loads(open(m.cache_path).read())
        for n in payload["artifact"]["decode"]["nodes"]:
            if n["kind"] == "cache_write":
                n["engine"] = "ita"
                break
        with open(m.cache_path, "w") as f:
            json.dump(payload, f)
        with pytest.raises(PlanVerificationError):
            api.compile(cfg, **kw)
        # verify=False still loads it (debugging escape hatch)
        m2 = api.compile(cfg, **kw, verify=False)
        assert m2.cache_hit

    def test_model_load_reverifies(self, cfg, tmp_path, dense_pair):
        m = api.compile(cfg, seq_len=8, max_len=14, fuse=False,
                        use_cache=False)
        path = str(tmp_path / "model.json")
        m.save(path)
        loaded = api.CompiledModel.load(path, cfg)
        assert loaded.verify_ms > 0.0
        payload = json.loads(open(path).read())
        for n in payload["artifact"]["decode"]["nodes"]:
            if n["kind"] == "cache_write":
                n["engine"] = "ita"
                break
        with open(path, "w") as f:
            json.dump(payload, f)
        with pytest.raises(PlanVerificationError):
            api.CompiledModel.load(path, cfg)
        assert api.CompiledModel.load(path, cfg, verify=False) is not None


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCli:
    def test_clean_artifacts_pass(self, fused_pair, paged_pair, tmp_path):
        a = str(tmp_path / "fused.json")
        b = str(tmp_path / "paged.json")
        fused_pair.save(a)
        paged_pair.save(b)
        assert main([a, b]) == 0
        assert main([a, b, "--strict"]) == 0

    def test_corrupt_artifact_fails(self, dense_pair, tmp_path, capsys):
        d = dense_pair.to_dict()
        d["decode"]["schedule"][0] = "bogus_node"
        path = str(tmp_path / "corrupt.json")
        with open(path, "w") as f:
            json.dump(d, f)
        assert main([path]) == 1
        out = capsys.readouterr().out
        assert "DF004" in out and "FAIL" in out

    def test_warnings_fail_only_under_strict(self, dense_pair, tmp_path):
        mutant = _decomp_warning_pair(dense_pair)
        path = str(tmp_path / "warn.json")
        mutant.save(path)
        assert main([path]) == 0
        assert main([path, "--strict"]) == 1

    def test_compiled_model_envelope_loads(self, cfg, tmp_path):
        m = api.compile(cfg, seq_len=8, max_len=14, use_cache=False)
        path = str(tmp_path / "model.json")
        m.save(path)
        artifact = load_artifact(path)
        assert isinstance(artifact, DecoderPlanPair)
        assert main([path, "--strict"]) == 0

    def test_unreadable_path_is_rc2(self, tmp_path):
        assert main([str(tmp_path / "missing.json")]) == 2


# ---------------------------------------------------------------------------
# satellite: structured binding errors
# ---------------------------------------------------------------------------

class TestBindingChecks:
    def test_weight_bind_lists_all_mismatches(self, cfg):
        from repro.deploy.executor import PlanBindingError, check_bindings

        m = api.compile(cfg, seq_len=8, max_len=14, fuse=False,
                        use_cache=False)
        weights, _ = m.bind()
        plan = m.artifact.prefill
        names = plan.weight_names[:2]
        broken = dict(weights)
        del broken[names[0]]
        broken[names[1]] = np.zeros((1, 1), np.int8)  # wrong shape
        with pytest.raises(PlanBindingError) as ei:
            check_bindings(plan, weights=broken)
        msg = str(ei.value)
        assert names[0] in msg and names[1] in msg
        assert len(ei.value.mismatches) == 2

    def test_clean_weights_bind(self, cfg):
        m = api.compile(cfg, seq_len=8, max_len=14, fuse=False,
                        use_cache=False)
        weights, _ = m.bind()  # _check_bound ran inside without raising
        assert weights

    def test_input_bind_rejects_bad_batch(self, cfg):
        from repro.deploy.executor import PlanBindingError, execute

        m = api.compile(cfg, seq_len=8, max_len=14, fuse=False,
                        use_cache=False)
        weights, _ = m.bind()
        plan = m.artifact.prefill
        with pytest.raises(PlanBindingError) as ei:
            execute(plan, weights, {"tokens": np.zeros((2, 9), np.int32)})
        assert "tokens" in str(ei.value)
        with pytest.raises(PlanBindingError) as ei:
            execute(plan, weights, {})
        assert "missing from the batch" in str(ei.value)


# ---------------------------------------------------------------------------
# satellite: structured memory-plan overlap reporting
# ---------------------------------------------------------------------------

class TestMemoryPlanError:
    def test_violations_name_pairs_and_ranges(self):
        from repro.deploy.memory import Allocation, MemoryPlan, MemoryPlanError

        a = Allocation("x", 0, 64, 0, 3)
        b = Allocation("y", 32, 64, 2, 5)
        plan = MemoryPlan({"x": a, "y": b}, peak=96)
        assert plan.overlap_violations() == [(a, b)]
        assert not plan.check_no_overlap()
        with pytest.raises(MemoryPlanError) as ei:
            plan.check()
        msg = str(ei.value)
        assert "x" in msg and "y" in msg and "[0, 64)" in msg \
            and "[32, 96)" in msg
        assert ei.value.violations == [(a, b)]

    def test_clean_plan_checks_through(self):
        from repro.deploy.memory import Allocation, MemoryPlan

        plan = MemoryPlan(
            {"x": Allocation("x", 0, 64, 0, 1),
             "y": Allocation("y", 0, 64, 2, 3)},  # disjoint lifetimes
            peak=64,
        )
        assert plan.check() is plan


# ---------------------------------------------------------------------------
# satellite: engine surfaces the one-time verification cost
# ---------------------------------------------------------------------------

class TestEngineVerifyMs:
    def test_stats_carry_verify_ms(self, cfg):
        from repro.deploy.engine import Engine

        m = api.compile(cfg, seq_len=8, max_len=14, use_cache=False)
        eng = Engine(m, max_batch=1)
        assert eng.stats.verify_ms == m.verify_ms > 0.0
        assert "verified" in eng.stats.summary()
        assert eng.reset_stats().verify_ms == m.verify_ms


# ---------------------------------------------------------------------------
# label plumbing
# ---------------------------------------------------------------------------

class TestDiagnosticShape:
    def test_labels_and_format(self, dense_pair):
        d = dense_pair.to_dict()
        d["decode"]["schedule"][0] = "bogus_node"
        mutant = DecoderPlanPair.from_dict(d, validate=False)
        diags = [x for x in verify(mutant) if x.rule == "DF004"]
        assert diags and diags[0].plan == "decode"
        line = diags[0].format()
        assert "ERROR" in line and "DF004" in line and "decode" in line

    def test_verify_plan_standalone(self, dense_pair):
        assert verify_plan(dense_pair.decode, "decode") == []
